//! System-under-test sampling (paper Fig 3): a sampled MWL paired with a
//! sampled MRR row, plus the cross-product population sampler used by every
//! experiment (paper §IV: "10,000 trials, using 100 multi-wavelength lasers
//! and 100 microring row samples").
//!
//! All scenario generalization (distribution family, correlation, fault
//! injection) is applied here, at sampling time, by threading
//! `cfg.scenario` into the per-device samplers — the sample records stay
//! dumb data.

use crate::config::SystemConfig;
use crate::model::scenario::DeviceSampling;
use crate::model::{MwlSample, RingRowSample};
use crate::rng::{derive_seed, Rng};

/// Kronecker low-discrepancy stride for laser devices: frac(φ), the
/// golden-ratio sequence (optimal one-dimensional discrepancy).
pub const STRATIFY_LASER_STRIDE: f64 = 0.618_033_988_749_894_9;

/// Kronecker stride for ring-row devices: √2 − 1, algebraically
/// independent of the laser stride so the two device axes never resonate.
pub const STRATIFY_ROW_STRIDE: f64 = 0.414_213_562_373_095_05;

/// `i`-th point of the shifted Kronecker sequence
/// `u_i = frac(shift + (i+1)·stride)`. Depends only on `(shift, i)`, which
/// is what makes stratified populations prefix-exact under doubling.
#[inline]
pub fn kronecker_point(shift: f64, stride: f64, i: usize) -> f64 {
    (shift + (i as f64 + 1.0) * stride).fract()
}

/// Seed-derived Cranley–Patterson rotation for the stratified sequence
/// (`lane` 0 = lasers, 1 = rows): different base seeds shift the whole
/// lattice, keeping replicated sweeps independent.
pub fn stratify_shift(seed: u64, lane: u64) -> f64 {
    Rng::seed_from(derive_seed(seed, &[0x9C, lane])).uniform01()
}

/// One arbitration trial's physical inputs.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemUnderTest {
    pub laser: MwlSample,
    pub rings: RingRowSample,
}

impl SystemUnderTest {
    /// Sample one laser + one ring row from the same stream.
    pub fn sample(cfg: &SystemConfig, rng: &mut Rng) -> Self {
        Self {
            laser: MwlSample::sample(&cfg.grid, &cfg.variation, &cfg.scenario, rng),
            rings: RingRowSample::sample(
                &cfg.grid,
                &cfg.pre_fab_order,
                cfg.ring_bias_nm,
                cfg.fsr_mean_nm,
                &cfg.variation,
                &cfg.scenario,
                rng,
            ),
        }
    }

    pub fn n_ch(&self) -> usize {
        self.laser.n_ch()
    }
}

/// Cross-product population: `n_lasers × n_rows` trials, each laser/row
/// sampled from an independent derived stream so the population is
/// reproducible and order-independent — under **every** scenario, since
/// scenario draws (including fault flags) stay within each device's own
/// stream.
#[derive(Debug, Clone)]
pub struct SystemSampler {
    pub lasers: Vec<MwlSample>,
    pub rows: Vec<RingRowSample>,
    /// Per-laser ln importance weight; empty unless the scenario's
    /// sampling design has an active tilt (so the plain path allocates
    /// nothing).
    pub laser_log_w: Vec<f64>,
    /// Per-row ln importance weight; empty unless tilted.
    pub row_log_w: Vec<f64>,
}

impl SystemSampler {
    pub fn new(cfg: &SystemConfig, n_lasers: usize, n_rows: usize, seed: u64) -> Self {
        let design = cfg.scenario.sampling;
        let tilted = design.tilt > 1.0;
        let (laser_shift, row_shift) = if design.stratified {
            (stratify_shift(seed, 0), stratify_shift(seed, 1))
        } else {
            (0.0, 0.0)
        };
        let mut laser_log_w = Vec::with_capacity(if tilted { n_lasers } else { 0 });
        let lasers = (0..n_lasers)
            .map(|i| {
                let mut rng = Rng::seed_from(derive_seed(seed, &[0xA5, i as u64]));
                if !design.active() {
                    return MwlSample::sample(&cfg.grid, &cfg.variation, &cfg.scenario, &mut rng);
                }
                let lead = design
                    .stratified
                    .then(|| kronecker_point(laser_shift, STRATIFY_LASER_STRIDE, i));
                let mut draws = DeviceSampling::for_device(&design, lead, &mut rng);
                let s = MwlSample::sample_with(
                    &cfg.grid,
                    &cfg.variation,
                    &cfg.scenario,
                    &mut rng,
                    &mut draws,
                );
                if tilted {
                    laser_log_w.push(draws.log_weight());
                }
                s
            })
            .collect();
        let mut row_log_w = Vec::with_capacity(if tilted { n_rows } else { 0 });
        let rows = (0..n_rows)
            .map(|j| {
                let mut rng = Rng::seed_from(derive_seed(seed, &[0x5A, j as u64]));
                if !design.active() {
                    return RingRowSample::sample(
                        &cfg.grid,
                        &cfg.pre_fab_order,
                        cfg.ring_bias_nm,
                        cfg.fsr_mean_nm,
                        &cfg.variation,
                        &cfg.scenario,
                        &mut rng,
                    );
                }
                let lead = design
                    .stratified
                    .then(|| kronecker_point(row_shift, STRATIFY_ROW_STRIDE, j));
                let mut draws = DeviceSampling::for_device(&design, lead, &mut rng);
                let s = RingRowSample::sample_with(
                    &cfg.grid,
                    &cfg.pre_fab_order,
                    cfg.ring_bias_nm,
                    cfg.fsr_mean_nm,
                    &cfg.variation,
                    &cfg.scenario,
                    &mut rng,
                    &mut draws,
                );
                if tilted {
                    row_log_w.push(draws.log_weight());
                }
                s
            })
            .collect();
        Self { lasers, rows, laser_log_w, row_log_w }
    }

    /// Is this a weighted (importance-tilted) population?
    #[inline]
    pub fn is_weighted(&self) -> bool {
        !self.laser_log_w.is_empty() || !self.row_log_w.is_empty()
    }

    /// ln importance weight of trial `t` (0 ⇒ weight 1 — every untilted
    /// population).
    #[inline]
    pub fn trial_log_weight(&self, t: usize) -> f64 {
        if !self.is_weighted() {
            return 0.0;
        }
        let rows = self.rows.len();
        let lw = self.laser_log_w.get(t / rows).copied().unwrap_or(0.0);
        let rw = self.row_log_w.get(t % rows).copied().unwrap_or(0.0);
        lw + rw
    }

    /// Importance weight of trial `t` (1 for untilted populations).
    #[inline]
    pub fn trial_weight(&self, t: usize) -> f64 {
        self.trial_log_weight(t).exp()
    }

    #[inline]
    pub fn n_trials(&self) -> usize {
        self.lasers.len() * self.rows.len()
    }

    /// Any fault-injected device in this population? (Backends that cannot
    /// represent faults — the XLA artifact — refuse such populations.)
    pub fn has_faults(&self) -> bool {
        self.lasers.iter().any(MwlSample::any_dead) || self.rows.iter().any(RingRowSample::any_dark)
    }

    /// Trial `t` = (laser `t / n_rows`, row `t % n_rows`). Cheap clone-free
    /// view used by the executor.
    #[inline]
    pub fn trial(&self, t: usize) -> (&MwlSample, &RingRowSample) {
        let rows = self.rows.len();
        (&self.lasers[t / rows], &self.rows[t % rows])
    }

    /// Materialize trial `t` as an owned `SystemUnderTest` (used by the
    /// oblivious simulator which mutates lock state around the samples).
    pub fn trial_owned(&self, t: usize) -> SystemUnderTest {
        let (l, r) = self.trial(t);
        SystemUnderTest { laser: l.clone(), rings: r.clone() }
    }

    /// Sub-sampler over lasers `[lo, hi)` with every row. Because each
    /// laser/row draws from its own derived stream, trial `t` of the slice
    /// is bit-identical to trial `lo·n_rows + t` of the full sampler —
    /// the adaptive scheduler grows a column's evaluated prefix in
    /// whole-laser blocks through exactly this window.
    pub fn slice_lasers(&self, lo: usize, hi: usize) -> SystemSampler {
        SystemSampler {
            lasers: self.lasers[lo..hi].to_vec(),
            rows: self.rows.clone(),
            laser_log_w: if self.laser_log_w.is_empty() {
                Vec::new()
            } else {
                self.laser_log_w[lo..hi].to_vec()
            },
            row_log_w: self.row_log_w.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::model::{CorrelationConfig, Distribution, FaultsConfig, ScenarioConfig};

    /// One representative config per scenario family: the determinism and
    /// prefix-exactness contracts must hold under every one of them (the
    /// adaptive `--ci` scheduler depends on it).
    fn scenario_configs() -> Vec<(&'static str, SystemConfig)> {
        let mut out = vec![("default", SystemConfig::default())];
        let mut gauss = SystemConfig::default();
        gauss.scenario.distribution = Distribution::by_name("trimmed-gaussian").unwrap();
        out.push(("trimmed-gaussian", gauss));
        let mut bimodal = SystemConfig::default();
        bimodal.scenario.distribution = Distribution::by_name("bimodal").unwrap();
        out.push(("bimodal", bimodal));
        let mut corr = SystemConfig::default();
        corr.scenario.correlation = CorrelationConfig { gradient_nm: 2.0, corr_len: 3.0 };
        out.push(("correlated", corr));
        let mut faulty = SystemConfig::default();
        faulty.scenario.faults = FaultsConfig {
            dead_tone_p: 0.2,
            dark_ring_p: 0.2,
            weak_ring_p: 0.2,
            weak_tr_factor: 0.5,
        };
        out.push(("faulty", faulty));
        out
    }

    #[test]
    fn sampler_is_reproducible() {
        let cfg = SystemConfig::default();
        let a = SystemSampler::new(&cfg, 5, 7, 99);
        let b = SystemSampler::new(&cfg, 5, 7, 99);
        assert_eq!(a.lasers, b.lasers);
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.n_trials(), 35);
    }

    #[test]
    fn different_seed_different_population() {
        let cfg = SystemConfig::default();
        let a = SystemSampler::new(&cfg, 3, 3, 1);
        let b = SystemSampler::new(&cfg, 3, 3, 2);
        assert_ne!(a.lasers, b.lasers);
    }

    #[test]
    fn trial_indexing_is_cross_product() {
        let cfg = SystemConfig::default();
        let s = SystemSampler::new(&cfg, 3, 4, 5);
        let (l, r) = s.trial(7); // laser 1, row 3
        assert_eq!(l, &s.lasers[1]);
        assert_eq!(r, &s.rows[3]);
    }

    #[test]
    fn laser_slice_matches_full_sampler_trials() {
        let cfg = SystemConfig::default();
        let full = SystemSampler::new(&cfg, 6, 4, 77);
        let slice = full.slice_lasers(2, 5);
        assert_eq!(slice.n_trials(), 12);
        for t in 0..slice.n_trials() {
            let (l, r) = slice.trial(t);
            let (fl, fr) = full.trial(2 * 4 + t);
            assert_eq!(l, fl, "trial {t}");
            assert_eq!(r, fr, "trial {t}");
        }
    }

    #[test]
    fn population_grows_with_first_samples_stable() {
        // Derived streams: laser i is identical whether we draw 5 or 50.
        let cfg = SystemConfig::default();
        let small = SystemSampler::new(&cfg, 5, 5, 42);
        let big = SystemSampler::new(&cfg, 50, 50, 42);
        assert_eq!(small.lasers[..], big.lasers[..5]);
        assert_eq!(small.rows[..], big.rows[..5]);
    }

    /// Satellite: determinism + `slice_lasers` prefix exactness under
    /// every scenario family, so adaptive `--ci` blocks stay exact
    /// truncations whatever the scenario.
    #[test]
    fn scenario_populations_deterministic_and_prefix_exact() {
        for (name, cfg) in scenario_configs() {
            let a = SystemSampler::new(&cfg, 6, 6, 123);
            let b = SystemSampler::new(&cfg, 6, 6, 123);
            assert_eq!(a.lasers, b.lasers, "{name}: reproducible lasers");
            assert_eq!(a.rows, b.rows, "{name}: reproducible rows");

            let small = SystemSampler::new(&cfg, 3, 6, 123);
            assert_eq!(small.lasers[..], a.lasers[..3], "{name}: laser prefix stable");
            assert_eq!(small.rows[..], a.rows[..], "{name}: rows identical");

            let slice = a.slice_lasers(1, 4);
            for t in 0..slice.n_trials() {
                let (l, r) = slice.trial(t);
                let (fl, fr) = a.trial(6 + t);
                assert_eq!(l, fl, "{name}: slice trial {t}");
                assert_eq!(r, fr, "{name}: slice trial {t}");
            }
        }
    }

    #[test]
    fn tilted_population_carries_bounded_weights_and_is_prefix_exact() {
        let mut cfg = SystemConfig::default();
        cfg.scenario.sampling.tilt = 8.0;
        let s = SystemSampler::new(&cfg, 6, 4, 31);
        assert!(s.is_weighted());
        assert_eq!(s.laser_log_w.len(), 6);
        assert_eq!(s.row_log_w.len(), 4);
        for t in 0..s.n_trials() {
            let w = s.trial_weight(t);
            assert!((0.0..=4.0 + 1e-9).contains(&w), "trial weight {w}");
        }
        // Prefix exactness: devices AND weights are stable under growth.
        let big = SystemSampler::new(&cfg, 12, 8, 31);
        assert_eq!(s.lasers[..], big.lasers[..6]);
        assert_eq!(s.laser_log_w[..], big.laser_log_w[..6]);
        assert_eq!(s.row_log_w[..], big.row_log_w[..4]);
        // slice_lasers slices the weights alongside the devices.
        let slice = big.slice_lasers(3, 9);
        for t in 0..slice.n_trials() {
            assert_eq!(
                slice.trial_log_weight(t).to_bits(),
                big.trial_log_weight(3 * 8 + t).to_bits(),
                "slice weight {t}"
            );
        }
    }

    #[test]
    fn untilted_population_has_unit_weights_and_no_weight_storage() {
        let s = SystemSampler::new(&SystemConfig::default(), 3, 3, 9);
        assert!(!s.is_weighted());
        assert!(s.laser_log_w.is_empty() && s.row_log_w.is_empty());
        assert_eq!(s.trial_weight(4), 1.0);
    }

    #[test]
    fn stratified_population_is_deterministic_and_prefix_exact() {
        let mut cfg = SystemConfig::default();
        cfg.scenario.sampling.stratified = true;
        let a = SystemSampler::new(&cfg, 8, 6, 55);
        let b = SystemSampler::new(&cfg, 8, 6, 55);
        assert_eq!(a.lasers, b.lasers);
        assert_eq!(a.rows, b.rows);
        assert!(!a.is_weighted(), "stratified draws carry no weights");
        // Doubling the population leaves every existing device untouched
        // (the Kronecker point depends only on the device index + seed).
        let big = SystemSampler::new(&cfg, 16, 12, 55);
        assert_eq!(a.lasers[..], big.lasers[..8]);
        assert_eq!(a.rows[..], big.rows[..6]);
        // The leading draw really is the Kronecker point: grid offsets are
        // the scaled sequence, and distinct from the plain-MC population.
        let shift = stratify_shift(55, 0);
        for (i, l) in a.lasers.iter().enumerate() {
            let u = kronecker_point(shift, STRATIFY_LASER_STRIDE, i);
            let want = (2.0 * u - 1.0) * cfg.variation.grid_offset_nm;
            assert_eq!(l.grid_offset_nm.to_bits(), want.to_bits(), "laser {i}");
        }
        let plain = SystemSampler::new(&SystemConfig::default(), 8, 6, 55);
        assert_ne!(a.lasers, plain.lasers);
    }

    #[test]
    fn fault_flags_surface_through_has_faults() {
        let (_, faulty) = scenario_configs().pop().unwrap();
        let s = SystemSampler::new(&faulty, 10, 10, 7);
        assert!(s.has_faults(), "p = 0.2 over 10 devices: a fault is near-certain");
        let clean = SystemSampler::new(&SystemConfig::default(), 3, 3, 7);
        assert!(!clean.has_faults());
    }
}
