//! System-under-test sampling (paper Fig 3): a sampled MWL paired with a
//! sampled MRR row, plus the cross-product population sampler used by every
//! experiment (paper §IV: "10,000 trials, using 100 multi-wavelength lasers
//! and 100 microring row samples").

use crate::config::SystemConfig;
use crate::model::{MwlSample, RingRowSample};
use crate::rng::{derive_seed, Rng};

/// One arbitration trial's physical inputs.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemUnderTest {
    pub laser: MwlSample,
    pub rings: RingRowSample,
}

impl SystemUnderTest {
    /// Sample one laser + one ring row from the same stream.
    pub fn sample(cfg: &SystemConfig, rng: &mut Rng) -> Self {
        Self {
            laser: MwlSample::sample(&cfg.grid, &cfg.variation, rng),
            rings: RingRowSample::sample(
                &cfg.grid,
                &cfg.pre_fab_order,
                cfg.ring_bias_nm,
                cfg.fsr_mean_nm,
                &cfg.variation,
                rng,
            ),
        }
    }

    pub fn n_ch(&self) -> usize {
        self.laser.n_ch()
    }
}

/// Cross-product population: `n_lasers × n_rows` trials, each laser/row
/// sampled from an independent derived stream so the population is
/// reproducible and order-independent.
#[derive(Debug, Clone)]
pub struct SystemSampler {
    pub lasers: Vec<MwlSample>,
    pub rows: Vec<RingRowSample>,
}

impl SystemSampler {
    pub fn new(cfg: &SystemConfig, n_lasers: usize, n_rows: usize, seed: u64) -> Self {
        let lasers = (0..n_lasers)
            .map(|i| {
                let mut rng = Rng::seed_from(derive_seed(seed, &[0xA5, i as u64]));
                MwlSample::sample(&cfg.grid, &cfg.variation, &mut rng)
            })
            .collect();
        let rows = (0..n_rows)
            .map(|j| {
                let mut rng = Rng::seed_from(derive_seed(seed, &[0x5A, j as u64]));
                RingRowSample::sample(
                    &cfg.grid,
                    &cfg.pre_fab_order,
                    cfg.ring_bias_nm,
                    cfg.fsr_mean_nm,
                    &cfg.variation,
                    &mut rng,
                )
            })
            .collect();
        Self { lasers, rows }
    }

    #[inline]
    pub fn n_trials(&self) -> usize {
        self.lasers.len() * self.rows.len()
    }

    /// Trial `t` = (laser `t / n_rows`, row `t % n_rows`). Cheap clone-free
    /// view used by the executor.
    #[inline]
    pub fn trial(&self, t: usize) -> (&MwlSample, &RingRowSample) {
        let rows = self.rows.len();
        (&self.lasers[t / rows], &self.rows[t % rows])
    }

    /// Materialize trial `t` as an owned `SystemUnderTest` (used by the
    /// oblivious simulator which mutates lock state around the samples).
    pub fn trial_owned(&self, t: usize) -> SystemUnderTest {
        let (l, r) = self.trial(t);
        SystemUnderTest { laser: l.clone(), rings: r.clone() }
    }

    /// Sub-sampler over lasers `[lo, hi)` with every row. Because each
    /// laser/row draws from its own derived stream, trial `t` of the slice
    /// is bit-identical to trial `lo·n_rows + t` of the full sampler —
    /// the adaptive scheduler grows a column's evaluated prefix in
    /// whole-laser blocks through exactly this window.
    pub fn slice_lasers(&self, lo: usize, hi: usize) -> SystemSampler {
        SystemSampler {
            lasers: self.lasers[lo..hi].to_vec(),
            rows: self.rows.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    #[test]
    fn sampler_is_reproducible() {
        let cfg = SystemConfig::default();
        let a = SystemSampler::new(&cfg, 5, 7, 99);
        let b = SystemSampler::new(&cfg, 5, 7, 99);
        assert_eq!(a.lasers, b.lasers);
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.n_trials(), 35);
    }

    #[test]
    fn different_seed_different_population() {
        let cfg = SystemConfig::default();
        let a = SystemSampler::new(&cfg, 3, 3, 1);
        let b = SystemSampler::new(&cfg, 3, 3, 2);
        assert_ne!(a.lasers, b.lasers);
    }

    #[test]
    fn trial_indexing_is_cross_product() {
        let cfg = SystemConfig::default();
        let s = SystemSampler::new(&cfg, 3, 4, 5);
        let (l, r) = s.trial(7); // laser 1, row 3
        assert_eq!(l, &s.lasers[1]);
        assert_eq!(r, &s.rows[3]);
    }

    #[test]
    fn laser_slice_matches_full_sampler_trials() {
        let cfg = SystemConfig::default();
        let full = SystemSampler::new(&cfg, 6, 4, 77);
        let slice = full.slice_lasers(2, 5);
        assert_eq!(slice.n_trials(), 12);
        for t in 0..slice.n_trials() {
            let (l, r) = slice.trial(t);
            let (fl, fr) = full.trial(2 * 4 + t);
            assert_eq!(l, fl, "trial {t}");
            assert_eq!(r, fr, "trial {t}");
        }
    }

    #[test]
    fn population_grows_with_first_samples_stable() {
        // Derived streams: laser i is identical whether we draw 5 or 50.
        let cfg = SystemConfig::default();
        let small = SystemSampler::new(&cfg, 5, 5, 42);
        let big = SystemSampler::new(&cfg, 50, 50, 42);
        assert_eq!(small.lasers[..], big.lasers[..5]);
        assert_eq!(small.rows[..], big.rows[..5]);
    }
}
