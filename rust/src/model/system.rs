//! System-under-test sampling (paper Fig 3): a sampled MWL paired with a
//! sampled MRR row, plus the cross-product population sampler used by every
//! experiment (paper §IV: "10,000 trials, using 100 multi-wavelength lasers
//! and 100 microring row samples").
//!
//! All scenario generalization (distribution family, correlation, fault
//! injection) is applied here, at sampling time, by threading
//! `cfg.scenario` into the per-device samplers — the sample records stay
//! dumb data.

use crate::config::SystemConfig;
use crate::model::{MwlSample, RingRowSample};
use crate::rng::{derive_seed, Rng};

/// One arbitration trial's physical inputs.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemUnderTest {
    pub laser: MwlSample,
    pub rings: RingRowSample,
}

impl SystemUnderTest {
    /// Sample one laser + one ring row from the same stream.
    pub fn sample(cfg: &SystemConfig, rng: &mut Rng) -> Self {
        Self {
            laser: MwlSample::sample(&cfg.grid, &cfg.variation, &cfg.scenario, rng),
            rings: RingRowSample::sample(
                &cfg.grid,
                &cfg.pre_fab_order,
                cfg.ring_bias_nm,
                cfg.fsr_mean_nm,
                &cfg.variation,
                &cfg.scenario,
                rng,
            ),
        }
    }

    pub fn n_ch(&self) -> usize {
        self.laser.n_ch()
    }
}

/// Cross-product population: `n_lasers × n_rows` trials, each laser/row
/// sampled from an independent derived stream so the population is
/// reproducible and order-independent — under **every** scenario, since
/// scenario draws (including fault flags) stay within each device's own
/// stream.
#[derive(Debug, Clone)]
pub struct SystemSampler {
    pub lasers: Vec<MwlSample>,
    pub rows: Vec<RingRowSample>,
}

impl SystemSampler {
    pub fn new(cfg: &SystemConfig, n_lasers: usize, n_rows: usize, seed: u64) -> Self {
        let lasers = (0..n_lasers)
            .map(|i| {
                let mut rng = Rng::seed_from(derive_seed(seed, &[0xA5, i as u64]));
                MwlSample::sample(&cfg.grid, &cfg.variation, &cfg.scenario, &mut rng)
            })
            .collect();
        let rows = (0..n_rows)
            .map(|j| {
                let mut rng = Rng::seed_from(derive_seed(seed, &[0x5A, j as u64]));
                RingRowSample::sample(
                    &cfg.grid,
                    &cfg.pre_fab_order,
                    cfg.ring_bias_nm,
                    cfg.fsr_mean_nm,
                    &cfg.variation,
                    &cfg.scenario,
                    &mut rng,
                )
            })
            .collect();
        Self { lasers, rows }
    }

    #[inline]
    pub fn n_trials(&self) -> usize {
        self.lasers.len() * self.rows.len()
    }

    /// Any fault-injected device in this population? (Backends that cannot
    /// represent faults — the XLA artifact — refuse such populations.)
    pub fn has_faults(&self) -> bool {
        self.lasers.iter().any(MwlSample::any_dead) || self.rows.iter().any(RingRowSample::any_dark)
    }

    /// Trial `t` = (laser `t / n_rows`, row `t % n_rows`). Cheap clone-free
    /// view used by the executor.
    #[inline]
    pub fn trial(&self, t: usize) -> (&MwlSample, &RingRowSample) {
        let rows = self.rows.len();
        (&self.lasers[t / rows], &self.rows[t % rows])
    }

    /// Materialize trial `t` as an owned `SystemUnderTest` (used by the
    /// oblivious simulator which mutates lock state around the samples).
    pub fn trial_owned(&self, t: usize) -> SystemUnderTest {
        let (l, r) = self.trial(t);
        SystemUnderTest { laser: l.clone(), rings: r.clone() }
    }

    /// Sub-sampler over lasers `[lo, hi)` with every row. Because each
    /// laser/row draws from its own derived stream, trial `t` of the slice
    /// is bit-identical to trial `lo·n_rows + t` of the full sampler —
    /// the adaptive scheduler grows a column's evaluated prefix in
    /// whole-laser blocks through exactly this window.
    pub fn slice_lasers(&self, lo: usize, hi: usize) -> SystemSampler {
        SystemSampler {
            lasers: self.lasers[lo..hi].to_vec(),
            rows: self.rows.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::model::{CorrelationConfig, Distribution, FaultsConfig, ScenarioConfig};

    /// One representative config per scenario family: the determinism and
    /// prefix-exactness contracts must hold under every one of them (the
    /// adaptive `--ci` scheduler depends on it).
    fn scenario_configs() -> Vec<(&'static str, SystemConfig)> {
        let mut out = vec![("default", SystemConfig::default())];
        let mut gauss = SystemConfig::default();
        gauss.scenario.distribution = Distribution::by_name("trimmed-gaussian").unwrap();
        out.push(("trimmed-gaussian", gauss));
        let mut bimodal = SystemConfig::default();
        bimodal.scenario.distribution = Distribution::by_name("bimodal").unwrap();
        out.push(("bimodal", bimodal));
        let mut corr = SystemConfig::default();
        corr.scenario.correlation = CorrelationConfig { gradient_nm: 2.0, corr_len: 3.0 };
        out.push(("correlated", corr));
        let mut faulty = SystemConfig::default();
        faulty.scenario.faults = FaultsConfig {
            dead_tone_p: 0.2,
            dark_ring_p: 0.2,
            weak_ring_p: 0.2,
            weak_tr_factor: 0.5,
        };
        out.push(("faulty", faulty));
        out
    }

    #[test]
    fn sampler_is_reproducible() {
        let cfg = SystemConfig::default();
        let a = SystemSampler::new(&cfg, 5, 7, 99);
        let b = SystemSampler::new(&cfg, 5, 7, 99);
        assert_eq!(a.lasers, b.lasers);
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.n_trials(), 35);
    }

    #[test]
    fn different_seed_different_population() {
        let cfg = SystemConfig::default();
        let a = SystemSampler::new(&cfg, 3, 3, 1);
        let b = SystemSampler::new(&cfg, 3, 3, 2);
        assert_ne!(a.lasers, b.lasers);
    }

    #[test]
    fn trial_indexing_is_cross_product() {
        let cfg = SystemConfig::default();
        let s = SystemSampler::new(&cfg, 3, 4, 5);
        let (l, r) = s.trial(7); // laser 1, row 3
        assert_eq!(l, &s.lasers[1]);
        assert_eq!(r, &s.rows[3]);
    }

    #[test]
    fn laser_slice_matches_full_sampler_trials() {
        let cfg = SystemConfig::default();
        let full = SystemSampler::new(&cfg, 6, 4, 77);
        let slice = full.slice_lasers(2, 5);
        assert_eq!(slice.n_trials(), 12);
        for t in 0..slice.n_trials() {
            let (l, r) = slice.trial(t);
            let (fl, fr) = full.trial(2 * 4 + t);
            assert_eq!(l, fl, "trial {t}");
            assert_eq!(r, fr, "trial {t}");
        }
    }

    #[test]
    fn population_grows_with_first_samples_stable() {
        // Derived streams: laser i is identical whether we draw 5 or 50.
        let cfg = SystemConfig::default();
        let small = SystemSampler::new(&cfg, 5, 5, 42);
        let big = SystemSampler::new(&cfg, 50, 50, 42);
        assert_eq!(small.lasers[..], big.lasers[..5]);
        assert_eq!(small.rows[..], big.rows[..5]);
    }

    /// Satellite: determinism + `slice_lasers` prefix exactness under
    /// every scenario family, so adaptive `--ci` blocks stay exact
    /// truncations whatever the scenario.
    #[test]
    fn scenario_populations_deterministic_and_prefix_exact() {
        for (name, cfg) in scenario_configs() {
            let a = SystemSampler::new(&cfg, 6, 6, 123);
            let b = SystemSampler::new(&cfg, 6, 6, 123);
            assert_eq!(a.lasers, b.lasers, "{name}: reproducible lasers");
            assert_eq!(a.rows, b.rows, "{name}: reproducible rows");

            let small = SystemSampler::new(&cfg, 3, 6, 123);
            assert_eq!(small.lasers[..], a.lasers[..3], "{name}: laser prefix stable");
            assert_eq!(small.rows[..], a.rows[..], "{name}: rows identical");

            let slice = a.slice_lasers(1, 4);
            for t in 0..slice.n_trials() {
                let (l, r) = slice.trial(t);
                let (fl, fr) = a.trial(6 + t);
                assert_eq!(l, fl, "{name}: slice trial {t}");
                assert_eq!(r, fr, "{name}: slice trial {t}");
            }
        }
    }

    #[test]
    fn fault_flags_surface_through_has_faults() {
        let (_, faulty) = scenario_configs().pop().unwrap();
        let s = SystemSampler::new(&faulty, 10, 10, 7);
        assert!(s.has_faults(), "p = 0.2 over 10 devices: a fault is near-certain");
        let clean = SystemSampler::new(&SystemConfig::default(), 3, 3, 7);
        assert!(!clean.has_faults());
    }
}
