//! Multi-wavelength-laser (MWL) model (paper Eq. (1) and (3)).

use crate::model::scenario::DeviceSampling;
use crate::model::{DwdmGrid, ScenarioConfig, VariationConfig};
use crate::rng::Rng;

/// One sampled multi-wavelength laser: `N_ch` tone wavelengths,
/// center-relative nm, index-ordered (tone `i` is the i-th grid slot; under
/// the paper's uniform scenario local variation is bounded by
/// ±σ_lLV·λ_gS ≤ 0.45·λ_gS in all experiments, so index order equals
/// wavelength order — heavy-tailed scenario distributions may relax this).
///
/// A *dumb data* record: fault flags are injected by the sampler, never
/// interpreted here.
#[derive(Debug, Clone, PartialEq)]
pub struct MwlSample {
    pub tones_nm: Vec<f64>,
    /// The sampled grid offset Δ_gO that was applied (kept for diagnostics).
    pub grid_offset_nm: f64,
    /// Per-tone dead flags (scenario fault injection: no optical power on
    /// that tone). Empty = every tone alive — the fault-free common case.
    pub dead: Vec<bool>,
}

impl MwlSample {
    /// Paper Eq. (3): `λ_laser,i = slot_i + Δ_gO + Δ_lLV,i` (center-relative),
    /// with each Δ drawn from the scenario's [`crate::model::Distribution`]
    /// and dead tones injected per the scenario's fault model.
    pub fn sample(
        grid: &DwdmGrid,
        var: &VariationConfig,
        scenario: &ScenarioConfig,
        rng: &mut Rng,
    ) -> Self {
        Self::sample_with(grid, var, scenario, rng, &mut DeviceSampling::Nominal)
    }

    /// [`Self::sample`] with an explicit per-device [`DeviceSampling`]
    /// controller (rare-event estimators). With `DeviceSampling::Nominal`
    /// the draws — and the RNG stream — are bit-identical to
    /// [`Self::sample`]. The leading draw is the grid offset Δ_gO (the
    /// stratified lead); fault draws always stay nominal.
    pub fn sample_with(
        grid: &DwdmGrid,
        var: &VariationConfig,
        scenario: &ScenarioConfig,
        rng: &mut Rng,
        draws: &mut DeviceSampling,
    ) -> Self {
        let dist = scenario.distribution;
        let offset = draws.draw(&dist, var.grid_offset_nm, rng);
        let local_half = var.laser_local_frac * grid.spacing_nm;
        let tones_nm = (0..grid.n_ch)
            .map(|i| grid.slot_nm(i) + offset + draws.draw(&dist, local_half, rng))
            .collect();
        let dead = scenario.faults.sample_dead_tones(grid.n_ch, rng);
        Self { tones_nm, grid_offset_nm: offset, dead }
    }

    /// Pre-fabrication / specification tones (paper Eq. (1)): no variation.
    pub fn nominal(grid: &DwdmGrid) -> Self {
        Self {
            tones_nm: (0..grid.n_ch).map(|i| grid.slot_nm(i)).collect(),
            grid_offset_nm: 0.0,
            dead: Vec::new(),
        }
    }

    #[inline]
    pub fn n_ch(&self) -> usize {
        self.tones_nm.len()
    }

    /// Is tone `j` dead (fault-injected)? Always false for fault-free
    /// samples, whose `dead` vector is empty.
    #[inline]
    pub fn tone_dead(&self, j: usize) -> bool {
        self.dead.get(j).copied().unwrap_or(false)
    }

    /// Any dead tone on this laser?
    #[inline]
    pub fn any_dead(&self) -> bool {
        self.dead.iter().any(|&d| d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tones_monotone_under_default_variation() {
        let grid = DwdmGrid::wdm8_g200();
        let var = VariationConfig::default();
        let scenario = ScenarioConfig::default();
        let mut rng = Rng::seed_from(11);
        for _ in 0..200 {
            let mwl = MwlSample::sample(&grid, &var, &scenario, &mut rng);
            for w in mwl.tones_nm.windows(2) {
                assert!(w[1] > w[0], "tones must stay index-ordered");
            }
        }
    }

    #[test]
    fn offset_bounded() {
        let grid = DwdmGrid::wdm8_g200();
        let var = VariationConfig::default();
        let scenario = ScenarioConfig::default();
        let mut rng = Rng::seed_from(12);
        for _ in 0..200 {
            let mwl = MwlSample::sample(&grid, &var, &scenario, &mut rng);
            assert!(mwl.grid_offset_nm.abs() <= var.grid_offset_nm);
        }
    }

    #[test]
    fn nominal_is_grid() {
        let grid = DwdmGrid::wdm8_g200();
        let mwl = MwlSample::nominal(&grid);
        assert!((mwl.tones_nm[0] + 3.5 * 1.12).abs() < 1e-12);
        assert!((mwl.tones_nm[7] - 3.5 * 1.12).abs() < 1e-12);
        assert!(!mwl.any_dead());
    }

    #[test]
    fn local_variation_bounded() {
        let grid = DwdmGrid::wdm8_g200();
        let var = VariationConfig { grid_offset_nm: 0.0, ..VariationConfig::default() };
        let scenario = ScenarioConfig::default();
        let mut rng = Rng::seed_from(13);
        for _ in 0..500 {
            let mwl = MwlSample::sample(&grid, &var, &scenario, &mut rng);
            for (i, &t) in mwl.tones_nm.iter().enumerate() {
                assert!((t - grid.slot_nm(i)).abs() <= 0.25 * grid.spacing_nm + 1e-12);
            }
        }
    }

    #[test]
    fn scenario_distribution_bounds_scale_with_support() {
        let grid = DwdmGrid::wdm8_g200();
        let var = VariationConfig { grid_offset_nm: 0.0, ..VariationConfig::default() };
        let scenario = ScenarioConfig {
            distribution: crate::model::Distribution::by_name("trimmed-gaussian").unwrap(),
            ..ScenarioConfig::default()
        };
        let support = scenario.distribution.support_nm(var.laser_local_frac * grid.spacing_nm);
        let mut rng = Rng::seed_from(14);
        for _ in 0..300 {
            let mwl = MwlSample::sample(&grid, &var, &scenario, &mut rng);
            for (i, &t) in mwl.tones_nm.iter().enumerate() {
                assert!((t - grid.slot_nm(i)).abs() <= support + 1e-12);
            }
        }
    }

    #[test]
    fn dead_tone_injection_flags_tones() {
        let grid = DwdmGrid::wdm8_g200();
        let var = VariationConfig::default();
        let scenario = ScenarioConfig {
            faults: crate::model::FaultsConfig { dead_tone_p: 1.0, ..Default::default() },
            ..ScenarioConfig::default()
        };
        let mut rng = Rng::seed_from(15);
        let mwl = MwlSample::sample(&grid, &var, &scenario, &mut rng);
        assert_eq!(mwl.dead.len(), 8);
        assert!((0..8).all(|j| mwl.tone_dead(j)));
        assert!(mwl.any_dead());

        // Fault-free samples never allocate fault flags.
        let clean = MwlSample::sample(&grid, &var, &ScenarioConfig::default(), &mut rng);
        assert!(clean.dead.is_empty());
        assert!(!clean.tone_dead(0));
    }
}
