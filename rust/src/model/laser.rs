//! Multi-wavelength-laser (MWL) model (paper Eq. (1) and (3)).

use crate::model::{DwdmGrid, VariationConfig};
use crate::rng::Rng;

/// One sampled multi-wavelength laser: `N_ch` tone wavelengths,
/// center-relative nm, index-ordered (tone `i` is the i-th grid slot; local
/// variation is bounded by ±σ_lLV·λ_gS ≤ 0.45·λ_gS in all experiments, so
/// index order equals wavelength order).
#[derive(Debug, Clone, PartialEq)]
pub struct MwlSample {
    pub tones_nm: Vec<f64>,
    /// The sampled grid offset Δ_gO that was applied (kept for diagnostics).
    pub grid_offset_nm: f64,
}

impl MwlSample {
    /// Paper Eq. (3): `λ_laser,i = slot_i + Δ_gO + Δ_lLV,i` (center-relative).
    pub fn sample(grid: &DwdmGrid, var: &VariationConfig, rng: &mut Rng) -> Self {
        let offset = rng.half_range(var.grid_offset_nm);
        let local_half = var.laser_local_frac * grid.spacing_nm;
        let tones_nm = (0..grid.n_ch)
            .map(|i| grid.slot_nm(i) + offset + rng.half_range(local_half))
            .collect();
        Self { tones_nm, grid_offset_nm: offset }
    }

    /// Pre-fabrication / specification tones (paper Eq. (1)): no variation.
    pub fn nominal(grid: &DwdmGrid) -> Self {
        Self {
            tones_nm: (0..grid.n_ch).map(|i| grid.slot_nm(i)).collect(),
            grid_offset_nm: 0.0,
        }
    }

    #[inline]
    pub fn n_ch(&self) -> usize {
        self.tones_nm.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tones_monotone_under_default_variation() {
        let grid = DwdmGrid::wdm8_g200();
        let var = VariationConfig::default();
        let mut rng = Rng::seed_from(11);
        for _ in 0..200 {
            let mwl = MwlSample::sample(&grid, &var, &mut rng);
            for w in mwl.tones_nm.windows(2) {
                assert!(w[1] > w[0], "tones must stay index-ordered");
            }
        }
    }

    #[test]
    fn offset_bounded() {
        let grid = DwdmGrid::wdm8_g200();
        let var = VariationConfig::default();
        let mut rng = Rng::seed_from(12);
        for _ in 0..200 {
            let mwl = MwlSample::sample(&grid, &var, &mut rng);
            assert!(mwl.grid_offset_nm.abs() <= var.grid_offset_nm);
        }
    }

    #[test]
    fn nominal_is_grid() {
        let grid = DwdmGrid::wdm8_g200();
        let mwl = MwlSample::nominal(&grid);
        assert!((mwl.tones_nm[0] + 3.5 * 1.12).abs() < 1e-12);
        assert!((mwl.tones_nm[7] - 3.5 * 1.12).abs() < 1e-12);
    }

    #[test]
    fn local_variation_bounded() {
        let grid = DwdmGrid::wdm8_g200();
        let var = VariationConfig { grid_offset_nm: 0.0, ..VariationConfig::default() };
        let mut rng = Rng::seed_from(13);
        for _ in 0..500 {
            let mwl = MwlSample::sample(&grid, &var, &mut rng);
            for (i, &t) in mwl.tones_nm.iter().enumerate() {
                assert!((t - grid.slot_nm(i)).abs() <= 0.25 * grid.spacing_nm + 1e-12);
            }
        }
    }
}
