//! # wdm-arbiter
//!
//! Full-system reproduction of *"Scalable Wavelength Arbitration for
//! Microring-based DWDM Transceivers"* (Choi & Stojanović, IEEE JLT,
//! DOI 10.1109/JLT.2025.3549686).
//!
//! The crate is the Layer-3 coordinator of a three-layer Rust + JAX + Pallas
//! stack (see `DESIGN.md`):
//!
//! * [`model`] — wavelength-domain device models: DWDM grid, multi-wavelength
//!   laser, microring row, uniform half-range variation sampling (paper §II-C,
//!   Table I).
//! * [`arbiter`] — the **ideal wavelength-aware arbitration model** (paper
//!   §III-A): scaled mod-FSR distance matrix, per-policy minimum tuning range
//!   (LtD / LtC / LtA incl. bottleneck bipartite matching).
//! * [`oblivious`] — the **wavelength-oblivious substrate and algorithms**
//!   (paper §V): tuner + optical-bus masking, wavelength-search tables,
//!   Relation Search (RS), Variation-Tolerant RS, Single-Step Matching (SSM)
//!   and the sequential Lock-to-Nearest baseline.
//! * [`metrics`] — AFP / CAFP accumulators and failure classification
//!   (paper §III, Fig 9(d–f)).
//! * [`montecarlo`] — the 100×100 laser/ring-row cross sampler, the
//!   thread-pool trial executor, and the **TrialEngine**
//!   ([`montecarlo::engine`]): unified ideal + oblivious evaluation with
//!   per-column population reuse — one sampled population and one
//!   ideal-model evaluation per sweep column, AFP by thresholding, CAFP
//!   gated on the precomputed ideal-LtC vector with per-worker arbitration
//!   workspaces ([`oblivious::Workspace`]). The **sweep scheduler**
//!   ([`montecarlo::scheduler`]) adds column-level parallelism on top: a
//!   work queue of whole columns with deterministic per-column seeds
//!   (panels bit-identical for any thread count), a bounded in-flight
//!   population count, a thread-safe coalescing population cache, and
//!   optional Wilson-interval adaptive trial allocation (`--ci`).
//! * [`coordinator::sweep`] — declarative **SweepSpec** layer: experiments
//!   submit (base config, column axis, λ̄_TR thresholds, measures) instead
//!   of hand-rolled nested loops; the `wdm-arbiter sweep` subcommand
//!   exposes ad-hoc grids over the same axes.
//! * [`runtime`] — PJRT CPU runtime behind the off-by-default `xla` cargo
//!   feature: loads the AOT-compiled JAX/Pallas ideal model
//!   (`artifacts/ideal_n{8,16}.hlo.txt`) and batch-executes it from the
//!   Rust hot path (Python is never on the request path). The default
//!   build compiles a stub that falls back to the pure-Rust backend.
//! * [`experiments`] + [`coordinator`] — one module per paper figure/table
//!   (all built on SweepSpec), an experiment registry, report writers
//!   (CSV / JSON / ASCII shmoo) and the launcher used by the `wdm-arbiter`
//!   binary.
//! * [`api`] — the **typed job API**: serializable
//!   [`api::JobRequest`]/[`api::JobResponse`] (JSON + TOML forms) and the
//!   long-lived [`api::ArbiterService`] that owns the backend evaluator
//!   and memoizes per-column populations across requests
//!   ([`montecarlo::PopulationCache`]). The CLI, `wdm-arbiter serve`
//!   (JSON-lines on stdin/stdout) and `wdm-arbiter batch jobs.json` are
//!   all thin clients of this service.
//! * [`fleet`] — horizontal scale-out: a coordinator that shards sweep
//!   columns across `serve --listen` worker nodes over the envelope
//!   protocol ([`fleet::FleetEvaluator`]), with per-worker
//!   heartbeat/backoff, re-issue of columns from dead workers, and
//!   scatter-by-index merging — fleet panels are bit-identical to
//!   single-node runs for any fleet size or completion order.
//!
//! ## Quickstart
//!
//! ```no_run
//! use wdm_arbiter::config::SystemConfig;
//! use wdm_arbiter::model::SystemUnderTest;
//! use wdm_arbiter::arbiter::{ideal, Policy};
//! use wdm_arbiter::rng::Rng;
//!
//! let cfg = SystemConfig::default(); // Table I defaults (wdm8, 200 GHz)
//! let mut rng = Rng::seed_from(42);
//! let sut = SystemUnderTest::sample(&cfg, &mut rng);
//! let dist = wdm_arbiter::arbiter::distance::scaled_distance_matrix(&sut);
//! let min_tr = ideal::min_tuning_range(Policy::LtC, &dist, cfg.target_order.as_slice());
//! println!("this trial needs a {min_tr:.2} nm mean tuning range under LtC");
//! ```

// `unsafe` is confined to the SIMD lane kernels: `util::simd` re-allows it
// locally (a `deny`, unlike `forbid`, can be overridden exactly there) and
// guards every intrinsic with debug assertions on its preconditions.
#![deny(unsafe_code)]

pub mod api;
pub mod arbiter;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod fleet;
pub mod metrics;
pub mod model;
pub mod montecarlo;
pub mod oblivious;
pub mod rng;
pub mod runtime;
pub mod testkit;
pub mod util;

/// Crate version, surfaced by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
