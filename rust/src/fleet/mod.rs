//! Fleet coordinator: shard sweep columns across worker nodes with
//! fault-tolerant, bit-identical merging.
//!
//! A *fleet* is any number of `wdm-arbiter serve --listen` processes; the
//! [`FleetEvaluator`] plugs in behind [`crate::api::ArbiterService`] (via
//! [`crate::montecarlo::scheduler::run_sweep_dispatched`]) and turns every
//! sweep job into per-column [`crate::api::JobRequest::Column`] wire jobs:
//!
//! * **Self-contained columns** — each job carries the coordinator's
//!   *resolved* config as inline TOML
//!   ([`crate::config::presets::system_config_to_toml`]), the full column
//!   value list, the base seed, and an FNV-1a fingerprint digest of the
//!   applied column config ([`crate::montecarlo::fingerprint_digest`]).
//!   Workers re-derive the column seed from the *index* and verify the
//!   digest, so a version-skewed or misconfigured node fails loudly
//!   instead of merging wrong bits.
//! * **Bit-identical merging** — cells travel as hex-encoded f64 bit
//!   patterns ([`crate::coordinator::sweep::MeasureColumn::to_json`]) and
//!   scatter back by column index through the same
//!   [`SweepSpec::scatter`] the local scheduler uses, so the merged panel
//!   is byte-identical to a single-node run for any fleet size,
//!   assignment order, or completion order.
//! * **Fault tolerance, training-launcher style** — each worker gets a
//!   dedicated coordinator thread pulling from a shared column queue.
//!   Connections open with a versioned `hello` handshake
//!   ([`crate::api::wire::PROTOCOL_VERSION`]); reads carry an idle timeout
//!   and unresponsive workers are probed with `status` controls before
//!   being declared dead. A dead or straggling worker's in-flight column
//!   is pushed back onto the queue and re-issued to survivors (idempotent:
//!   seeds derive from the column index); reconnects use exponential
//!   backoff, and a worker that comes back is re-admitted. When every
//!   worker is gone, the coordinator finishes the leftovers locally
//!   (`--local-fallback`) or fails with a structured error.
//! * **Cancellation** — a fired [`CancelToken`] propagates as `cancel`
//!   controls to every worker with an in-flight column; the sweep returns
//!   `Err(`[`SWEEP_CANCELED`]`)` with no partial panels.
//!
//! [`harness::WorkerHarness`] spawns real TCP workers in-process (port 0)
//! so the whole stack — protocol, failover, merging — runs in `cargo test`
//! without external processes.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::Duration;

use crate::api::request::{ConfigSpec, JobRequest};
use crate::api::wire::PROTOCOL_VERSION;
use crate::config::presets::system_config_to_toml;
use crate::coordinator::sweep::{column_seed, ColumnEval, SweepSpec};
use crate::coordinator::RunOptions;
use crate::montecarlo::{
    fingerprint_digest, CancelToken, ColumnProgress, EvalFactory, PopulationCache, RemoteColumns,
    SWEEP_CANCELED, SweepRun, TrialEngine,
};
use crate::util::json::Json;

pub mod harness;

/// Fleet topology and failure-detection knobs. The duration fields exist
/// so tests can run failure paths in milliseconds; the defaults suit real
/// deployments.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    /// Worker addresses (`host:port`), one coordinator thread each.
    pub workers: Vec<String>,
    /// Finish leftover columns locally when the whole fleet is gone (and
    /// run fully locally when `workers` is empty).
    pub local_fallback: bool,
    /// Per-attempt TCP connect timeout.
    pub connect_timeout: Duration,
    /// Read timeout while waiting on a worker; each expiry triggers a
    /// liveness probe (a `status` control) rather than immediate death, so
    /// long columns don't look like hung workers.
    pub io_timeout: Duration,
    /// Consecutive unanswered probes before a worker is declared dead.
    pub max_probes: usize,
    /// Reconnect attempts (exponential backoff) before a worker's
    /// coordinator thread gives up; the budget refills on every served
    /// column, so a flaky-but-working node is kept, a gone node is not.
    pub max_reconnects: usize,
    /// First reconnect delay; doubles per attempt, capped at 1 s.
    pub backoff_base: Duration,
}

impl FleetSpec {
    pub fn new(workers: Vec<String>) -> FleetSpec {
        FleetSpec {
            workers,
            local_fallback: false,
            connect_timeout: Duration::from_secs(2),
            io_timeout: Duration::from_secs(1),
            max_probes: 120,
            max_reconnects: 3,
            backoff_base: Duration::from_millis(50),
        }
    }

    /// Parse a CLI worker list: comma-separated `host:port` entries.
    pub fn parse(list: &str) -> Result<FleetSpec, String> {
        let workers: Vec<String> = list
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect();
        for w in &workers {
            if !w.contains(':') {
                return Err(format!("fleet: worker '{w}' is not host:port"));
            }
        }
        Ok(FleetSpec::new(workers))
    }

    pub fn local_fallback(mut self, on: bool) -> FleetSpec {
        self.local_fallback = on;
        self
    }
}

/// Per-worker accounting for one fleet sweep.
#[derive(Debug, Clone)]
pub struct WorkerStats {
    pub addr: String,
    /// Columns this worker served to completion.
    pub columns: usize,
    /// Columns this worker started but returned to the queue (connection
    /// lost or worker unresponsive mid-column).
    pub reissued: usize,
    /// Connection (re)attempts beyond the first successful one.
    pub reconnects: usize,
    /// Population-cache activity reported by the worker, summed over its
    /// column responses (the cache-key exchange: the coordinator sends the
    /// config fingerprint, the worker reports hits/misses back).
    pub cache_hits: usize,
    pub cache_misses: usize,
    /// Worker-reported release version from the `hello` handshake.
    pub release: String,
    /// Still usable when the sweep finished.
    pub alive: bool,
    /// Why the worker was abandoned (when `alive` is false) or its last
    /// transient failure.
    pub error: Option<String>,
}

impl WorkerStats {
    fn new(addr: &str) -> WorkerStats {
        WorkerStats {
            addr: addr.to_string(),
            columns: 0,
            reissued: 0,
            reconnects: 0,
            cache_hits: 0,
            cache_misses: 0,
            release: String::new(),
            alive: true,
            error: None,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("addr", Json::str(self.addr.clone())),
            ("columns", Json::num(self.columns as f64)),
            ("reissued", Json::num(self.reissued as f64)),
            ("reconnects", Json::num(self.reconnects as f64)),
            (
                "cache",
                Json::obj(vec![
                    ("hits", Json::num(self.cache_hits as f64)),
                    ("misses", Json::num(self.cache_misses as f64)),
                ]),
            ),
            ("release", Json::str(self.release.clone())),
            ("alive", Json::Bool(self.alive)),
        ];
        if let Some(e) = &self.error {
            pairs.push(("error", Json::str(e.clone())));
        }
        Json::obj(pairs)
    }
}

/// One fleet sweep's bookkeeping, attached to the sweep's [`JobResponse`]
/// data (never to `sweep.json`, which stays byte-identical to a local run).
#[derive(Debug, Clone)]
pub struct FleetRunStats {
    pub workers: Vec<WorkerStats>,
    /// Columns the coordinator finished locally after losing the fleet.
    pub local_columns: usize,
    pub n_cols: usize,
}

impl FleetRunStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_cols", Json::num(self.n_cols as f64)),
            ("local_columns", Json::num(self.local_columns as f64)),
            (
                "workers",
                Json::Arr(self.workers.iter().map(WorkerStats::to_json).collect()),
            ),
        ])
    }

    /// One human-readable line for the sweep summary.
    pub fn summary_line(&self) -> String {
        let served: usize = self.workers.iter().map(|w| w.columns).sum();
        let alive = self.workers.iter().filter(|w| w.alive).count();
        let reissued: usize = self.workers.iter().map(|w| w.reissued).sum();
        let hits: usize = self.workers.iter().map(|w| w.cache_hits).sum();
        let misses: usize = self.workers.iter().map(|w| w.cache_misses).sum();
        format!(
            "fleet: {served}/{} columns over {alive}/{} workers \
             ({reissued} reissued, {} local), worker caches {hits} hits / {misses} misses\n",
            self.n_cols,
            self.workers.len(),
            self.local_columns,
        )
    }
}

/// The coordinator: implements [`RemoteColumns`] by sharding a sweep's
/// columns across the fleet and merging the returned cells by index.
/// Stateless between runs except for [`Self::last_run_stats`].
pub struct FleetEvaluator {
    spec: FleetSpec,
    last: Mutex<Option<FleetRunStats>>,
}

impl FleetEvaluator {
    pub fn new(spec: FleetSpec) -> FleetEvaluator {
        FleetEvaluator { spec, last: Mutex::new(None) }
    }

    pub fn spec(&self) -> &FleetSpec {
        &self.spec
    }

    /// Bookkeeping of the most recent completed fleet sweep (`None` before
    /// the first, after an empty-fleet fallback, or after a failed run).
    pub fn last_run_stats(&self) -> Option<FleetRunStats> {
        self.last.lock().ok().and_then(|g| g.clone())
    }
}

/// Cross-thread state of one fleet sweep.
struct RunShared {
    /// Columns nobody owns right now; failed workers push theirs back.
    pending: Mutex<VecDeque<usize>>,
    /// Columns not yet served; worker threads exit when it hits zero.
    remaining: AtomicUsize,
    /// Stop everything (cancel, fatal error, or completion).
    abort: AtomicBool,
    /// First fatal (non-transient) error: version mismatch, fingerprint
    /// mismatch, a structured job failure. Fails the whole sweep.
    fatal: Mutex<Option<String>>,
}

impl RunShared {
    fn aborted(&self) -> bool {
        self.abort.load(Ordering::Acquire)
    }

    fn push_back(&self, ix: usize) {
        if let Ok(mut q) = self.pending.lock() {
            q.push_front(ix);
        }
    }

    fn set_fatal(&self, msg: String) {
        if let Ok(mut f) = self.fatal.lock() {
            f.get_or_insert(msg);
        }
        self.abort.store(true, Ordering::Release);
    }
}

/// Everything a worker thread needs, borrowed for the scope of one run.
struct RunCtx<'a> {
    fs: &'a FleetSpec,
    /// Prebuilt `{"id":"c<ix>","request":{column job}}` envelope lines.
    jobs: &'a [String],
    shared: &'a RunShared,
    stats: &'a Mutex<Vec<WorkerStats>>,
    backends: &'a Mutex<Vec<String>>,
    cancel: &'a CancelToken,
}

impl RunCtx<'_> {
    fn stopped(&self) -> bool {
        self.cancel.is_canceled() || self.shared.aborted()
    }

    fn with_stats(&self, slot: usize, f: impl FnOnce(&mut WorkerStats)) {
        if let Ok(mut st) = self.stats.lock() {
            f(&mut st[slot]);
        }
    }
}

/// A worker failed in a way that retrying (elsewhere or later) can fix —
/// versus a structural error that would fail identically anywhere.
enum ColErr {
    Conn(String),
    Fatal(String),
    Canceled,
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Read one `\n`-terminated line, preserving partial reads across timeout
/// errors. `BufRead::read_line` must not be used here: on a mid-line read
/// timeout it discards the bytes it already consumed (its UTF-8 guard
/// truncates on error), silently corrupting the stream. `read_until` keeps
/// them in `buf`, so the next call resumes the same line.
fn read_wire_line(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
) -> std::io::Result<Option<String>> {
    reader.read_until(b'\n', buf)?;
    if buf.last() == Some(&b'\n') {
        let line = String::from_utf8_lossy(buf).trim().to_string();
        buf.clear();
        return Ok(Some(line));
    }
    // No delimiter and no error: EOF, possibly mid-line (the worker died
    // while writing). The partial line is unusable either way.
    Ok(None)
}

/// One live worker connection, `hello`-handshaken.
struct Conn {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    buf: Vec<u8>,
    release: String,
    /// Monotonic probe counter: probe envelope ids must stay unique for
    /// the connection's lifetime (the server rejects duplicate ids).
    probe_seq: usize,
}

enum ConnError {
    /// Worth retrying with backoff (refused, timed out, mid-handshake EOF).
    Retry(String),
    /// Permanent: protocol version mismatch, no `column` capability.
    Fatal(String),
}

impl Conn {
    fn establish(addr: &str, fs: &FleetSpec) -> Result<Conn, ConnError> {
        let sock = addr
            .to_socket_addrs()
            .ok()
            .and_then(|mut it| it.next())
            .ok_or_else(|| ConnError::Retry(format!("cannot resolve '{addr}'")))?;
        let stream = TcpStream::connect_timeout(&sock, fs.connect_timeout)
            .map_err(|e| ConnError::Retry(e.to_string()))?;
        let _ = stream.set_nodelay(true);
        stream
            .set_read_timeout(Some(fs.io_timeout))
            .map_err(|e| ConnError::Retry(e.to_string()))?;
        let clone = stream.try_clone().map_err(|e| ConnError::Retry(e.to_string()))?;
        let mut conn = Conn {
            stream,
            reader: BufReader::new(clone),
            buf: Vec::new(),
            release: String::new(),
            probe_seq: 0,
        };
        conn.handshake(fs)?;
        Ok(conn)
    }

    /// Pin the protocol version and check the worker answers `column`
    /// jobs. A mismatch is fatal for the run — a worker speaking another
    /// protocol would fail (or worse, drift) on every column.
    fn handshake(&mut self, fs: &FleetSpec) -> Result<(), ConnError> {
        let hello = Json::obj(vec![
            ("id", Json::str("hello")),
            ("control", Json::str("hello")),
            ("version", Json::num(PROTOCOL_VERSION as f64)),
        ]);
        writeln!(self.stream, "{}", hello.to_string())
            .map_err(|e| ConnError::Retry(e.to_string()))?;
        let mut probes = 0usize;
        loop {
            match read_wire_line(&mut self.reader, &mut self.buf) {
                Ok(None) => return Err(ConnError::Retry("closed during handshake".to_string())),
                Ok(Some(text)) => {
                    let Ok(j) = Json::parse(&text) else {
                        return Err(ConnError::Retry(format!("handshake garbage: {text}")));
                    };
                    if j.get("id").and_then(Json::as_str) != Some("hello") {
                        continue;
                    }
                    let Some(resp) = j.get("response") else { continue };
                    if resp.get("ok").and_then(Json::as_bool) != Some(true) {
                        let err = resp
                            .get("error")
                            .and_then(Json::as_str)
                            .unwrap_or("hello failed")
                            .to_string();
                        return Err(ConnError::Fatal(err));
                    }
                    let data = resp.get("data");
                    let has_column = data
                        .and_then(|d| d.get("capabilities"))
                        .and_then(Json::as_arr)
                        .is_some_and(|caps| caps.iter().any(|c| c.as_str() == Some("column")));
                    if !has_column {
                        return Err(ConnError::Fatal(
                            "worker does not answer column jobs (older release?)".to_string(),
                        ));
                    }
                    self.release = data
                        .and_then(|d| d.get("release"))
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string();
                    return Ok(());
                }
                Err(e) if is_timeout(&e) => {
                    probes += 1;
                    if probes >= fs.max_probes.max(1) {
                        return Err(ConnError::Retry("handshake timed out".to_string()));
                    }
                }
                Err(e) => return Err(ConnError::Retry(e.to_string())),
            }
        }
    }

    /// Submit one column and wait for its response, probing liveness with
    /// `status` controls on read timeouts. Skips interleaved event lines;
    /// any response under a different id (probe and cancel acks) proves
    /// the worker is alive and resets the probe budget.
    fn run_column(
        &mut self,
        ix: usize,
        line: &str,
        ctx: &RunCtx<'_>,
    ) -> Result<(usize, ColumnEval, usize, usize, String), ColErr> {
        writeln!(self.stream, "{line}").map_err(|e| ColErr::Conn(e.to_string()))?;
        let want = format!("c{ix}");
        let mut probes = 0usize;
        loop {
            match read_wire_line(&mut self.reader, &mut self.buf) {
                Ok(None) => return Err(ColErr::Conn("connection closed".to_string())),
                Ok(Some(text)) => {
                    let Ok(j) = Json::parse(&text) else {
                        return Err(ColErr::Conn(format!("unparseable line: {text}")));
                    };
                    let Some(resp) = j.get("response") else { continue };
                    if j.get("id").and_then(Json::as_str) != Some(want.as_str()) {
                        probes = 0; // any answered envelope proves liveness
                        continue;
                    }
                    if resp.get("canceled").and_then(Json::as_bool) == Some(true) {
                        return Err(ColErr::Canceled);
                    }
                    if resp.get("ok").and_then(Json::as_bool) != Some(true) {
                        let err = resp
                            .get("error")
                            .and_then(Json::as_str)
                            .unwrap_or("column job failed")
                            .to_string();
                        return Err(ColErr::Fatal(err));
                    }
                    return parse_column_response(resp).map_err(ColErr::Fatal);
                }
                Err(e) if is_timeout(&e) => {
                    if ctx.stopped() {
                        // Propagate the cancel to the worker (best effort)
                        // so its job stops at the next cancel point instead
                        // of burning trials for a response nobody reads.
                        let cancel = Json::obj(vec![
                            ("id", Json::str(format!("x{ix}"))),
                            ("control", Json::str("cancel")),
                            ("job", Json::str(want.clone())),
                        ]);
                        let _ = writeln!(self.stream, "{}", cancel.to_string());
                        let _ = self.stream.flush();
                        return Err(ColErr::Canceled);
                    }
                    if probes >= ctx.fs.max_probes {
                        return Err(ColErr::Conn(format!(
                            "unresponsive: {probes} probes unanswered"
                        )));
                    }
                    self.probe_seq += 1;
                    probes += 1;
                    let probe = Json::obj(vec![
                        ("id", Json::str(format!("p{}", self.probe_seq))),
                        ("control", Json::str("status")),
                        ("job", Json::str(want.clone())),
                    ]);
                    writeln!(self.stream, "{}", probe.to_string())
                        .map_err(|e| ColErr::Conn(e.to_string()))?;
                }
                Err(e) => return Err(ColErr::Conn(e.to_string())),
            }
        }
    }
}

/// Extract `(n_trials, cells, cache_hits, cache_misses, backend)` from a
/// successful column response.
fn parse_column_response(
    resp: &Json,
) -> Result<(usize, ColumnEval, usize, usize, String), String> {
    let data = resp.get("data").ok_or("column response has no data")?;
    let n_trials = data
        .get("n_trials")
        .and_then(Json::as_usize)
        .ok_or("column response has no n_trials")?;
    let cells =
        ColumnEval::from_json(data.get("cells").ok_or("column response has no cells")?)?;
    let counter = |key: &str| {
        resp.get("cache")
            .and_then(|c| c.get(key))
            .and_then(Json::as_usize)
            .unwrap_or(0)
    };
    let backend = resp
        .get("backend")
        .and_then(Json::as_str)
        .unwrap_or("")
        .to_string();
    Ok((n_trials, cells, counter("hits"), counter("misses"), backend))
}

/// The per-worker coordinator thread: pull a column, ensure a live
/// connection (reconnect with backoff), run it, report the cells. On any
/// connection-class failure the column goes back to the shared queue for
/// a survivor; on a fatal error the whole run aborts.
fn worker_loop(
    ctx: &RunCtx<'_>,
    slot: usize,
    addr: &str,
    tx: &mpsc::Sender<(usize, usize, ColumnEval)>,
) {
    let mut conn: Option<Conn> = None;
    let mut budget = ctx.fs.max_reconnects;
    let mut backoff = ctx.fs.backoff_base;
    loop {
        if ctx.stopped() || ctx.shared.remaining.load(Ordering::Acquire) == 0 {
            return;
        }
        let next = ctx.shared.pending.lock().ok().and_then(|mut q| q.pop_front());
        let Some(ix) = next else {
            // Another worker's in-flight column may yet come back; stay up.
            std::thread::sleep(Duration::from_millis(2));
            continue;
        };
        while conn.is_none() {
            if ctx.stopped() {
                ctx.shared.push_back(ix);
                return;
            }
            match Conn::establish(addr, ctx.fs) {
                Ok(c) => {
                    let release = c.release.clone();
                    ctx.with_stats(slot, |st| st.release = release);
                    conn = Some(c);
                    backoff = ctx.fs.backoff_base;
                }
                Err(ConnError::Fatal(e)) => {
                    ctx.shared.push_back(ix);
                    ctx.with_stats(slot, |st| {
                        st.alive = false;
                        st.error = Some(e.clone());
                    });
                    ctx.shared.set_fatal(format!("fleet worker {addr}: {e}"));
                    return;
                }
                Err(ConnError::Retry(e)) => {
                    if budget == 0 {
                        ctx.shared.push_back(ix);
                        ctx.with_stats(slot, |st| {
                            st.alive = false;
                            st.error = Some(e);
                        });
                        return;
                    }
                    budget -= 1;
                    ctx.with_stats(slot, |st| st.reconnects += 1);
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_secs(1));
                }
            }
        }
        match conn.as_mut().expect("just connected").run_column(ix, &ctx.jobs[ix], ctx) {
            Ok((n_trials, cells, hits, misses, backend)) => {
                ctx.with_stats(slot, |st| {
                    st.columns += 1;
                    st.cache_hits += hits;
                    st.cache_misses += misses;
                });
                if let Ok(mut b) = ctx.backends.lock() {
                    b.push(backend);
                }
                let _ = tx.send((ix, n_trials, cells));
                ctx.shared.remaining.fetch_sub(1, Ordering::AcqRel);
                budget = ctx.fs.max_reconnects;
            }
            Err(ColErr::Canceled) => return,
            Err(ColErr::Fatal(e)) => {
                ctx.with_stats(slot, |st| {
                    st.alive = false;
                    st.error = Some(e.clone());
                });
                ctx.shared.set_fatal(format!("fleet worker {addr}: {e}"));
                return;
            }
            Err(ColErr::Conn(e)) => {
                ctx.shared.push_back(ix);
                conn = None;
                // Charge the reconnect budget here too: a node that keeps
                // accepting connections but never finishes a column must
                // not hold the coordinator hostage forever.
                if budget == 0 {
                    ctx.with_stats(slot, |st| {
                        st.alive = false;
                        st.error = Some(e);
                    });
                    return;
                }
                budget -= 1;
                ctx.with_stats(slot, |st| {
                    st.reissued += 1;
                    st.error = Some(e);
                });
            }
        }
    }
}

/// Evaluator names the fleet can report as a single `'static` backend tag;
/// mixed or unknown fleets report `"fleet"`.
fn fleet_backend(names: &[String]) -> &'static str {
    let mut uniq: Vec<&str> = names.iter().map(String::as_str).collect();
    uniq.sort_unstable();
    uniq.dedup();
    match uniq.as_slice() {
        ["rust-f64"] => "rust-f64",
        ["xla-pjrt"] => "xla-pjrt",
        ["rust-oblivious"] => "rust-oblivious",
        ["none"] => "none",
        _ => "fleet",
    }
}

impl RemoteColumns for FleetEvaluator {
    fn run(
        &self,
        spec: &SweepSpec,
        opts: &RunOptions,
        factory: &dyn EvalFactory,
        cache: Option<&PopulationCache>,
        cancel: &CancelToken,
        progress: &mut dyn FnMut(ColumnProgress),
    ) -> Result<Option<SweepRun>, String> {
        if let Ok(mut g) = self.last.lock() {
            *g = None;
        }
        if self.spec.workers.is_empty() {
            return if self.spec.local_fallback {
                Ok(None) // degrade to the plain local scheduler
            } else {
                Err("fleet: no workers configured \
                     (pass --local-fallback to run without a fleet)"
                    .to_string())
            };
        }
        let n_cols = spec.values.len();
        // Prebuild every column job envelope: the resolved base config as
        // inline TOML plus the fingerprint digest of the *applied* column
        // config, so both sides prove they resolve identical configs.
        let cfg_toml = system_config_to_toml(&spec.base);
        let jobs: Vec<String> = (0..n_cols)
            .map(|ix| {
                let req = JobRequest::Column {
                    tag: spec.tag.clone(),
                    lane: spec.lane,
                    axis: spec.axis,
                    values: spec.values.clone(),
                    ix,
                    thresholds: spec.tr_values.clone(),
                    measures: spec.measures.clone(),
                    config: ConfigSpec {
                        path: None,
                        inline_toml: Some(cfg_toml.clone()),
                        permuted: false,
                    },
                    seed: opts.seed,
                    lasers: opts.n_lasers,
                    rows: opts.n_rows,
                    fingerprint: fingerprint_digest(&spec.axis.apply(&spec.base, spec.values[ix])),
                };
                Json::obj(vec![
                    ("id", Json::str(format!("c{ix}"))),
                    ("request", req.to_json()),
                ])
                .to_string()
            })
            .collect();

        let shared = RunShared {
            pending: Mutex::new((0..n_cols).collect()),
            remaining: AtomicUsize::new(n_cols),
            abort: AtomicBool::new(false),
            fatal: Mutex::new(None),
        };
        let stats: Mutex<Vec<WorkerStats>> =
            Mutex::new(self.spec.workers.iter().map(|a| WorkerStats::new(a)).collect());
        let backends: Mutex<Vec<String>> = Mutex::new(Vec::new());
        let ctx = RunCtx {
            fs: &self.spec,
            jobs: &jobs,
            shared: &shared,
            stats: &stats,
            backends: &backends,
            cancel,
        };

        let mut outs = spec.empty_outputs();
        let mut done = vec![false; n_cols];
        let mut n_done = 0usize;
        let (tx, rx) = mpsc::channel::<(usize, usize, ColumnEval)>();
        std::thread::scope(|s| {
            for (slot, addr) in self.spec.workers.iter().enumerate() {
                let tx = tx.clone();
                let ctx = &ctx;
                s.spawn(move || worker_loop(ctx, slot, addr, &tx));
            }
            drop(tx);
            // The merge: scatter by index as results arrive (any order).
            // The loop ends when every worker thread has exited — normal
            // completion, cancel, all-dead, or fatal.
            while let Ok((ix, n_trials, cells)) = rx.recv() {
                if cancel.is_canceled() {
                    shared.abort.store(true, Ordering::Release);
                }
                if !done[ix] {
                    done[ix] = true;
                    n_done += 1;
                    spec.scatter(&mut outs, ix, cells);
                    progress(ColumnProgress { ix, n_cols, value: spec.values[ix], n_trials });
                }
            }
        });

        if cancel.is_canceled() {
            return Err(SWEEP_CANCELED.to_string());
        }
        if let Some(e) = shared.fatal.lock().ok().and_then(|mut f| f.take()) {
            if n_done < n_cols {
                return Err(e);
            }
            // The sweep completed despite the late fatal (e.g. a stale
            // worker joined after the work was done); keep the result, the
            // per-worker stats carry the error.
        }
        // Every worker is gone and columns remain: finish locally (the
        // degraded single-node mode) or fail structurally.
        let mut local_columns = 0usize;
        if n_done < n_cols {
            let leftover: Vec<usize> = (0..n_cols).filter(|&i| !done[i]).collect();
            if !self.spec.local_fallback {
                return Err(format!(
                    "fleet: all {} workers failed with {} of {n_cols} columns unfinished \
                     (pass --local-fallback to finish them locally)",
                    self.spec.workers.len(),
                    leftover.len(),
                ));
            }
            let eval = factory.make(opts.threads);
            let mut engine = TrialEngine::new(eval.as_ref(), opts.threads);
            if let Some(c) = cache {
                engine = engine.with_cache(c);
            }
            let policies = spec.column_policies();
            if let Ok(mut b) = backends.lock() {
                b.push(eval.name().to_string());
            }
            for ix in leftover {
                if cancel.is_canceled() {
                    return Err(SWEEP_CANCELED.to_string());
                }
                let cfg = spec.axis.apply(&spec.base, spec.values[ix]);
                let seed = column_seed(opts.seed, &spec.tag, spec.lane, ix);
                let pop = engine.population(&cfg, opts.n_lasers, opts.n_rows, seed, &policies);
                let cells = spec.eval_column(&cfg, &pop, &engine);
                spec.scatter(&mut outs, ix, cells);
                progress(ColumnProgress {
                    ix,
                    n_cols,
                    value: spec.values[ix],
                    n_trials: pop.n_trials(),
                });
                local_columns += 1;
            }
        }

        let backend = fleet_backend(&backends.into_inner().unwrap_or_default());
        if let Ok(mut g) = self.last.lock() {
            *g = Some(FleetRunStats {
                workers: stats.into_inner().unwrap_or_default(),
                local_columns,
                n_cols,
            });
        }
        Ok(Some(SweepRun { outputs: outs, backend, stats: None }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_spec_parses_worker_lists() {
        let fs = FleetSpec::parse("a:1, b:2 ,,c:3").unwrap();
        assert_eq!(fs.workers, vec!["a:1", "b:2", "c:3"]);
        assert!(!fs.local_fallback);
        assert!(FleetSpec::parse("localhost").is_err());
        assert_eq!(FleetSpec::parse("").unwrap().workers.len(), 0);
    }

    #[test]
    fn backend_interning_prefers_uniform_names() {
        let names = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<_>>();
        assert_eq!(fleet_backend(&names(&["rust-f64", "rust-f64"])), "rust-f64");
        assert_eq!(fleet_backend(&names(&["rust-f64", "xla-pjrt"])), "fleet");
        assert_eq!(fleet_backend(&names(&["weird"])), "fleet");
        assert_eq!(fleet_backend(&[]), "fleet");
    }

    #[test]
    fn empty_fleet_degrades_only_with_local_fallback() {
        use crate::arbiter::Policy;
        use crate::coordinator::sweep::{ConfigAxis, Measure};
        use crate::coordinator::Backend;
        let spec = SweepSpec::new(
            "sweep",
            crate::config::SystemConfig::default(),
            ConfigAxis::RingLocalNm,
            vec![1.12],
        )
        .measure(Measure::MinTrComplete(Policy::LtC));
        let opts = RunOptions { n_lasers: 2, n_rows: 2, ..RunOptions::fast() };
        let cancel = CancelToken::new();
        let mut on_col = |_p: ColumnProgress| {};

        let fallback = FleetEvaluator::new(FleetSpec::new(vec![]).local_fallback(true));
        let r = fallback.run(&spec, &opts, &Backend::Rust, None, &cancel, &mut on_col);
        assert!(matches!(r, Ok(None)), "empty fleet + fallback defers to local");
        assert!(fallback.last_run_stats().is_none());

        let strict = FleetEvaluator::new(FleetSpec::new(vec![]));
        let r = strict.run(&spec, &opts, &Backend::Rust, None, &cancel, &mut on_col);
        assert!(r.unwrap_err().contains("no workers configured"));
    }
}
