//! In-process fleet workers for tests and demos.
//!
//! [`WorkerHarness::spawn`] binds a real TCP listener on an OS-assigned
//! port and serves the full envelope protocol from a background thread —
//! the coordinator talks to it exactly as it would to a remote
//! `wdm-arbiter serve --listen` process, so protocol, failover and merge
//! behavior are all exercised inside `cargo test`. [`WorkerHarness::kill`]
//! hard-stops the listener (connections torn down mid-write, in-flight
//! responses lost) to simulate a crashed node.

use std::thread::JoinHandle;

use crate::api::{ArbiterService, ListenCtl, WireListener};
use crate::coordinator::Backend;

/// One spawned in-process worker node.
pub struct WorkerHarness {
    addr: String,
    ctl: ListenCtl,
    thread: Option<JoinHandle<()>>,
}

impl WorkerHarness {
    /// Bind `127.0.0.1:0` and serve a fresh [`ArbiterService`] from a
    /// background thread until stopped.
    pub fn spawn(backend: Backend, threads: usize) -> Result<WorkerHarness, String> {
        let listener = WireListener::bind("127.0.0.1:0", None)?;
        let addr = listener.local_addr().to_string();
        let ctl = listener.control();
        let thread = std::thread::Builder::new()
            .name(format!("fleet-worker-{addr}"))
            .spawn(move || {
                let service = ArbiterService::new(backend, threads);
                listener.serve(&service);
            })
            .map_err(|e| e.to_string())?;
        Ok(WorkerHarness { addr, ctl, thread: Some(thread) })
    }

    /// The worker's `host:port`, for [`crate::fleet::FleetSpec`].
    pub fn addr(&self) -> String {
        self.addr.clone()
    }

    /// Simulate a crash: tear down the listener and every open connection
    /// without draining, then reap the server thread.
    pub fn kill(&mut self) {
        self.ctl.stop(true);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for WorkerHarness {
    fn drop(&mut self) {
        self.ctl.stop(false);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}
