//! Offline shim for the subset of the `anyhow` crate used by `wdm-arbiter`.
//!
//! The build environment carries no crates.io registry (DESIGN.md
//! "Substitutions"), so this vendored crate provides the same surface the
//! workspace relies on: [`Error`], [`Result`], the [`anyhow!`] macro and the
//! [`Context`] extension trait. Errors are stored as a flat message chain;
//! `{e}` prints the outermost message, `{e:#}` prints the full chain
//! separated by `": "` — matching real `anyhow` closely enough for CLI
//! output and tests. Swap the path dependency for `anyhow = "1"` to use the
//! real crate when online.

use std::fmt;

/// A flattened error: root cause first, contexts appended outermost-last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.chain.push(ctx.to_string());
        self
    }

    /// The messages, outermost first (like `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, outermost context first.
            for (i, msg) in self.chain.iter().rev().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.chain.last().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:#}", self)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// would conflict with the blanket `From<E: std::error::Error>` below (same
// trick as real anyhow).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Flatten the source chain; storage is root-cause-first so Display
        // shows the outermost message.
        let mut msgs = vec![e.to_string()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = cur {
            msgs.push(s.to_string());
            cur = s.source();
        }
        msgs.reverse();
        Error { chain: msgs }
    }
}

/// `anyhow::Result`, defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an ad-hoc [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an ad-hoc error (parity with `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

/// Extension trait adding `.context()` / `.with_context()` to `Result` and
/// `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Error::msg("root").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root");
    }

    #[test]
    fn from_std_error_and_question_mark() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(format!("{e}").contains("gone"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").contains("gone"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(format!("{e}"), "missing x");
    }

    #[test]
    fn anyhow_macro_forms() {
        let a = anyhow!("plain");
        let b = anyhow!("fmt {}", 7);
        let s = String::from("owned");
        let c = anyhow!(s);
        assert_eq!(format!("{a}"), "plain");
        assert_eq!(format!("{b}"), "fmt 7");
        assert_eq!(format!("{c}"), "owned");
    }
}
