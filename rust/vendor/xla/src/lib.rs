//! Offline **stub** of the PJRT/XLA binding surface `wdm-arbiter`'s
//! `runtime` module compiles against when the `xla` cargo feature is on.
//!
//! Every entry point returns [`Error::Stub`]: enabling the feature keeps the
//! code compiling and the CLI working (the coordinator falls back to the
//! pure-Rust backend with a warning), without pulling heavyweight native
//! dependencies into the build. To run the real AOT JAX/Pallas artifacts,
//! point the `xla` path dependency in `rust/Cargo.toml` at actual PJRT
//! bindings exposing this same surface (e.g. xla-rs).

use std::fmt;

/// Stub error: the only error this crate ever produces.
#[derive(Debug)]
pub enum Error {
    Stub,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "built against the vendored xla stub; point rust/vendor/xla at real PJRT bindings"
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// A host literal (stub: carries no data).
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Stub)
    }

    pub fn to_tuple4(&self) -> Result<(Literal, Literal, Literal, Literal)> {
        Err(Error::Stub)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Stub)
    }
}

/// A device buffer handle returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Stub)
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Stub)
    }
}

/// An XLA computation ready to compile.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Stub)
    }
}

/// A PJRT client.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Stub: always errors — callers fall back to the pure-Rust backend.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Stub)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Stub)
    }
}
