//! Determinism property tests for the column-parallel sweep scheduler:
//! a sweep's full panel output must be **bit-identical** across worker
//! thread counts, across queue hand-out orderings, across in-flight
//! bounds, and across cache-cold vs cache-warm service runs. Together with
//! `tests/golden.rs` these lock the scheduler's seeded reproducibility
//! down so future refactors cannot silently perturb sampling or tally
//! order.
//!
//! CI runs the whole test suite under a `threads={1,4}` matrix via the
//! `WDM_TEST_THREADS` env var; these tests additionally fold that value
//! into their thread sets so the matrix exercises distinct schedules.

use wdm_arbiter::arbiter::Policy;
use wdm_arbiter::api::{ArbiterService, JobRequest};
use wdm_arbiter::config::SystemConfig;
use wdm_arbiter::coordinator::sweep::{ConfigAxis, Measure, SweepSpec};
use wdm_arbiter::coordinator::{AdaptiveCfg, Backend, RunOptions};
use wdm_arbiter::montecarlo::scheduler::{run_sweep, run_sweep_ordered, ColumnOrder};
use wdm_arbiter::montecarlo::{CancelToken, RustIdeal, TrialEngine};
use wdm_arbiter::oblivious::Scheme;

/// Thread counts to exercise: the ISSUE's {1, 2, 8} plus the CI matrix
/// value (if any).
fn thread_counts() -> Vec<usize> {
    let mut counts = vec![1, 2, 8];
    if let Ok(v) = std::env::var("WDM_TEST_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if !counts.contains(&n) {
                counts.push(n);
            }
        }
    }
    counts
}

fn spec() -> SweepSpec {
    SweepSpec::new(
        "determinism",
        SystemConfig::default(),
        ConfigAxis::RingLocalNm,
        vec![0.56, 1.12, 2.24, 3.36, 4.48],
    )
    .thresholds(vec![2.0, 4.0, 6.0, 9.0])
    .measures([
        Measure::Afp(Policy::LtC),
        Measure::Cafp(Scheme::VtRsSsm),
        Measure::MinTrComplete(Policy::LtA),
    ])
}

fn opts(threads: usize) -> RunOptions {
    RunOptions { n_lasers: 6, n_rows: 6, threads, ..RunOptions::fast() }
}

/// Panels are bit-identical for every worker thread count, and identical
/// to the sequential single-engine reference.
#[test]
fn sweep_panels_identical_across_thread_counts() {
    let spec = spec();
    let reference = {
        let ideal = RustIdeal { threads: 1 };
        let engine = TrialEngine::new(&ideal, 1);
        spec.run(&engine, &opts(1))
    };
    for threads in thread_counts() {
        let run =
            run_sweep(&spec, &opts(threads), &Backend::Rust, None, &CancelToken::new(), &mut |_| {})
                .unwrap();
        assert_eq!(
            run.outputs, reference,
            "threads={threads} must be bit-identical to the sequential run"
        );
    }
}

/// Queue hand-out order (and therefore completion order) never affects
/// the output: forward and reverse orderings agree bit-for-bit.
#[test]
fn sweep_panels_identical_across_column_orderings() {
    let spec = spec();
    for threads in [2, 8] {
        let fwd = run_sweep_ordered(
            &spec,
            &opts(threads),
            &Backend::Rust,
            None,
            &CancelToken::new(),
            ColumnOrder::Forward,
            &mut |_| {},
        )
        .unwrap();
        let rev = run_sweep_ordered(
            &spec,
            &opts(threads),
            &Backend::Rust,
            None,
            &CancelToken::new(),
            ColumnOrder::Reverse,
            &mut |_| {},
        )
        .unwrap();
        assert_eq!(fwd.outputs, rev.outputs, "threads={threads}");
    }
}

/// Bounding in-flight populations reshapes the schedule, not the result.
#[test]
fn sweep_panels_identical_under_inflight_bounds() {
    let spec = spec();
    let unbounded =
        run_sweep(&spec, &opts(8), &Backend::Rust, None, &CancelToken::new(), &mut |_| {})
            .unwrap();
    for inflight in [1, 2, 3] {
        let bounded = run_sweep(
            &spec,
            &RunOptions { max_inflight: inflight, ..opts(8) },
            &Backend::Rust,
            None,
            &CancelToken::new(),
            &mut |_| {},
        )
        .unwrap();
        assert_eq!(unbounded.outputs, bounded.outputs, "inflight={inflight}");
    }
}

/// Adaptive (--ci) allocation is just as deterministic: same panels and
/// same per-cell trial counts for any thread count.
#[test]
fn adaptive_sweep_identical_across_thread_counts() {
    let spec = SweepSpec::new(
        "determinism-ci",
        SystemConfig::default(),
        ConfigAxis::RingLocalNm,
        vec![1.12, 2.24, 4.48],
    )
    .thresholds(vec![2.0, 6.0])
    .measures([Measure::Afp(Policy::LtC), Measure::Cafp(Scheme::RsSsm)]);
    let ci = Some(AdaptiveCfg { width: 0.3, min_trials: 12, max_trials: 36 });
    let base = RunOptions { n_lasers: 6, n_rows: 6, ci, ..RunOptions::fast() };
    let reference = run_sweep(&spec, &base, &Backend::Rust, None, &CancelToken::new(), &mut |_| {}).unwrap();
    for threads in thread_counts() {
        let run = run_sweep(
            &spec,
            &RunOptions { threads, ..base.clone() },
            &Backend::Rust,
            None,
            &CancelToken::new(),
            &mut |_| {},
        )
        .unwrap();
        assert_eq!(run.outputs, reference.outputs, "threads={threads}");
        assert_eq!(
            run.stats.as_ref().unwrap(),
            reference.stats.as_ref().unwrap(),
            "threads={threads}: per-cell n_trials and intervals must match"
        );
    }
}

fn sweep_job(out: &std::path::Path) -> JobRequest {
    JobRequest::from_json_str(&format!(
        r#"{{"type":"sweep","axis":"ring-local","values":[1.12,2.24,3.36],"tr":[2,6],
            "measures":["afp:ltc","cafp:vt-rs-ssm"],
            "options":{{"fast":true,"lasers":4,"rows":4,"out":"{}"}}}}"#,
        out.display()
    ))
    .unwrap()
}

/// A cache-warm `ArbiterService` run (second submission, populations all
/// memoized) produces panels bit-identical to its cache-cold first run —
/// and to a fresh service entirely.
#[test]
fn service_runs_identical_cache_cold_and_warm() {
    let dir = std::env::temp_dir().join(format!("wdm-det-svc-{}", std::process::id()));
    let job = sweep_job(&dir);

    let service = ArbiterService::new(Backend::Rust, 2);
    let cold = service.submit(&job);
    assert!(cold.ok, "{:?}", cold.error);
    assert!(cold.cache.misses > 0, "first run samples");
    let warm = service.submit(&job);
    assert!(warm.ok, "{:?}", warm.error);
    assert_eq!(warm.cache.misses, 0, "second run is fully cached");
    assert_eq!(cold.panels, warm.panels, "cache state must not perturb panels");

    let fresh = ArbiterService::new(Backend::Rust, 2).submit(&job);
    assert_eq!(fresh.panels, cold.panels, "fresh service agrees too");
    std::fs::remove_dir_all(&dir).ok();
}
