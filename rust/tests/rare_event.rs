//! Rare-event estimation acceptance tests (the ISSUE's headline check):
//! a *planted* configuration whose exact tail probability is known in
//! closed form, recovered by the importance-sampling estimator at 1e-6
//! with 100× fewer trials than the binomial rule-of-three bound, with
//! bit-identical panels across thread counts and through a worker fleet.
//!
//! ## The planted tail
//!
//! Only `variation.grid_offset_nm` is nonzero (σ), every other variation
//! source and the ring bias are zeroed. Each trial then reduces to a
//! common-mode laser-comb offset `x ~ Uniform(−σ, σ)` against rings that
//! sit exactly on the grid, so the ideal LtC margin is
//! `min_tr = min_k |k·spacing − x|` over cyclic lock assignments. With
//! σ = 0.5 < spacing/2 = 0.56 (default 8-channel grid, 1.12 nm spacing)
//! the k = 0 assignment always wins and **min_tr = |x| exactly**, giving
//! the closed form
//!
//! ```text
//! AFP(tr) = P(|x| > tr) = (σ − tr) / σ        for 0 ≤ tr ≤ σ.
//! ```
//!
//! Planting `tr = σ·(1 − 1e-6)` makes the failure probability exactly
//! 1e-6; a calibration row at `tr = σ/2` (truth 0.5) catches any drift in
//! the margin model itself before the tail assertions run.

use std::time::Duration;

use wdm_arbiter::api::{ArbiterService, ConfigSpec, JobOptions, JobRequest, Panel};
use wdm_arbiter::arbiter::Policy;
use wdm_arbiter::config::SystemConfig;
use wdm_arbiter::coordinator::sweep::{ConfigAxis, Measure, SweepOutput, SweepSpec};
use wdm_arbiter::coordinator::{Backend, RunOptions};
use wdm_arbiter::fleet::harness::WorkerHarness;
use wdm_arbiter::fleet::{FleetEvaluator, FleetSpec};
use wdm_arbiter::montecarlo::rareevent::splitting_afp;
use wdm_arbiter::montecarlo::scheduler::run_sweep;
use wdm_arbiter::montecarlo::CancelToken;
use wdm_arbiter::oblivious::Scheme;
use wdm_arbiter::util::cli::Args;
use wdm_arbiter::util::json::Json;

/// Planted comb-offset spread; must stay below spacing/2 = 0.56 nm so the
/// k = 0 lock assignment dominates and min_tr = |x| exactly.
const SIGMA: f64 = 0.5;
/// Planted threshold: AFP(tr) = (σ − tr)/σ = 1e-6 exactly.
const PLANTED_TR: f64 = SIGMA * (1.0 - 1.0e-6);
/// Calibration threshold: AFP = 0.5 — validates the margin model.
const CAL_TR: f64 = SIGMA / 2.0;
/// Trials per cell. The binomial rule-of-three bound for resolving 1e-6
/// is 3/1e-6 = 3,000,000 trials; 30,000 is exactly 100× below it, so the
/// plain estimator is provably blind here while IS is not.
const N_TRIALS: usize = 30_000;
/// Importance tilt: the tilted proposal's outer shell [σ(1−1/τ), σ]
/// covers the failure region (width 5e-7 of shell width 5e-6), so ~10 %
/// of tilted shell draws land in it — ≈1500 weighted hits per run.
const TILT: f64 = 1.0e5;

/// Zero every variation source except the comb offset (set per-column by
/// the grid-offset sweep axis), and put the rings exactly on the grid.
fn planted_toml() -> String {
    "[variation]\n\
     laser_local_frac = 0.0\n\
     ring_local_nm = 0.0\n\
     fsr_frac = 0.0\n\
     tr_frac = 0.0\n\
     [design]\n\
     ring_bias_nm = 0.0\n"
        .to_string()
}

/// The same planted config as a [`SystemConfig`] value (for the direct
/// `SweepSpec` / `splitting_afp` tests that bypass the job API).
fn planted_config() -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.variation.grid_offset_nm = SIGMA;
    cfg.variation.laser_local_frac = 0.0;
    cfg.variation.ring_local_nm = 0.0;
    cfg.variation.fsr_frac = 0.0;
    cfg.variation.tr_frac = 0.0;
    cfg.ring_bias_nm = 0.0;
    cfg
}

/// Sweep job over the planted config: one grid-offset column at σ, a
/// calibration row and the planted 1e-6 row.
fn planted_job(
    dir: &std::path::Path,
    seed: u64,
    threads: usize,
    estimator: Option<(&str, f64)>,
) -> JobRequest {
    let mut options = JobOptions {
        lasers: Some(N_TRIALS),
        rows: Some(1),
        seed: Some(seed),
        threads: Some(threads),
        out: Some(dir.display().to_string()),
        ..JobOptions::default()
    };
    if let Some((kind, tilt)) = estimator {
        options.estimator = Some(kind.to_string());
        if kind == "importance" {
            options.tilt = Some(tilt);
        }
    }
    JobRequest::Sweep {
        axis: ConfigAxis::GridOffsetNm,
        values: vec![SIGMA],
        thresholds: Some(vec![CAL_TR, PLANTED_TR]),
        measures: vec![Measure::Afp(Policy::LtC)],
        config: ConfigSpec { path: None, inline_toml: Some(planted_toml()), permuted: false },
        options,
    }
}

fn test_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("wdm-rare-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Run a planted job on a fresh service; return `(cells, n, lo, hi)` in
/// row-major order `[calibration, planted]` (nx = 1, ny = 2).
fn run_planted(
    tag: &str,
    seed: u64,
    threads: usize,
    estimator: Option<(&str, f64)>,
) -> (Vec<f64>, Vec<usize>, Vec<f64>, Vec<f64>) {
    let dir = test_dir(tag);
    let service = ArbiterService::new(Backend::Rust, threads);
    let resp = service.submit(&planted_job(&dir, seed, threads, estimator));
    assert!(resp.ok, "{tag}: {:?}", resp.error);
    let Panel::Grid { cells, stats: Some(stats), .. } = &resp.panels[0] else {
        panic!("{tag}: sweep must produce a grid panel with stats");
    };
    assert_eq!(cells.len(), 2, "{tag}: 1 column x 2 thresholds");
    let out =
        (cells.clone(), stats.n_trials.clone(), stats.ci_lo.clone(), stats.ci_hi.clone());
    std::fs::remove_dir_all(dir).ok();
    out
}

/// The headline acceptance test: plain Monte Carlo is blind to the
/// planted 1e-6 tail at 30,000 trials (100× under the rule-of-three
/// bound), importance sampling recovers it inside its reported 95 % CI,
/// and the weighted panels are bit-identical on 1 and 4 threads.
#[test]
fn importance_recovers_planted_one_in_a_million_tail() {
    // (a) Plain estimator: calibration row is dead-on (SE ≈ 0.003, the
    // 0.02 gate is ~7σ — this is what certifies min_tr = |x|), while the
    // planted row reads ~0 (P(any hit) = 1 − (1−1e-6)^30000 ≈ 3 %; even
    // one lucky hit is 1/30000 ≈ 3.3e-5 < 1e-4).
    let (cells, n, _, _) = run_planted("plain", 11, 2, None);
    assert!(
        (cells[0] - 0.5).abs() < 0.02,
        "calibration row must read 0.5 under plain sampling, got {}",
        cells[0]
    );
    assert!(
        cells[1] < 1.0e-4,
        "plain sampling must be blind to the 1e-6 tail at 30k trials, got {}",
        cells[1]
    );
    assert_eq!(n, vec![N_TRIALS, N_TRIALS]);

    // (b) Importance sampling, five seeds: each point estimate lands
    // within a factor-of-a-few of 1e-6 (relative SE ≈ 2.6 % — the
    // (2e-7, 5e-6) gate is enormous slack), and the reported 95 % CI
    // covers the truth for a strict majority of seeds.
    let mut covered = 0usize;
    for seed in [11u64, 22, 33, 44, 55] {
        let (cells, n, lo, hi) =
            run_planted(&format!("is-{seed}"), seed, 2, Some(("importance", TILT)));
        assert_eq!(n, vec![N_TRIALS, N_TRIALS], "IS evaluates the full tilted population");
        assert!(
            (cells[0] - 0.5).abs() < 0.03,
            "seed {seed}: weighted calibration row drifted: {}",
            cells[0]
        );
        let p = cells[1];
        assert!(
            (2.0e-7..5.0e-6).contains(&p),
            "seed {seed}: IS estimate {p} not within a factor of ~4 of 1e-6"
        );
        assert!(
            0.0 < lo[1] && lo[1] <= p && p <= hi[1] && hi[1] < 1.0e-4,
            "seed {seed}: malformed interval [{}, {}] around {p}",
            lo[1],
            hi[1]
        );
        if lo[1] <= 1.0e-6 && 1.0e-6 <= hi[1] {
            covered += 1;
        }
    }
    assert!(covered >= 3, "95% CI must cover the planted truth for >=3/5 seeds, got {covered}");

    // (c) Thread invariance: the weighted fold is sequential in trial
    // order, so fresh services on 1 and 4 threads must agree bit for bit.
    let a = run_planted("is-t1", 11, 1, Some(("importance", TILT)));
    let b = run_planted("is-t4", 11, 4, Some(("importance", TILT)));
    assert_eq!(a.1, b.1, "trial counts must match across thread counts");
    for (x, y) in a.0.iter().zip(&b.0).chain(a.2.iter().zip(&b.2)).chain(a.3.iter().zip(&b.3)) {
        assert_eq!(x.to_bits(), y.to_bits(), "threads {{1,4}} panels must be bit-identical");
    }
}

/// The estimator selection survives argv → JobRequest → JSON → JobRequest
/// and the equivalent hand-written TOML job file parses to the same
/// request — one estimator of each parameterized kind.
#[test]
fn estimator_round_trips_cli_json_toml() {
    let cases: &[(&[&str], &str)] = &[
        (
            &["--estimator", "importance", "--tilt", "100000"],
            "estimator = \"importance\"\ntilt = 100000.0\n",
        ),
        (&["--estimator", "splitting", "--levels", "24"], "estimator = \"splitting\"\nlevels = 24\n"),
        (&["--estimator", "stratified"], "estimator = \"stratified\"\n"),
    ];
    for (extra, toml_knobs) in cases {
        let mut argv: Vec<String> = [
            "sweep", "--axis", "grid-offset", "--values", "0.5", "--tr", "4.6", "--lasers", "64",
            "--rows", "4", "--seed", "7",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        argv.extend(extra.iter().map(|s| s.to_string()));
        let args = Args::parse(&argv, &["fast", "cases", "permuted", "help"]).unwrap();
        let from_cli = wdm_arbiter::api::cli::job_from_args(&args).unwrap();

        let from_json = JobRequest::from_json_str(&from_cli.to_json_string()).unwrap();
        assert_eq!(from_json, from_cli, "JSON round-trip must be lossless");

        let toml = format!(
            "[job]\ntype = \"sweep\"\naxis = \"grid-offset\"\nvalues = [0.5]\ntr = [4.6]\n\
             [job.options]\nlasers = 64\nrows = 4\nseed = 7\n{toml_knobs}"
        );
        let from_toml = JobRequest::from_toml(&toml).unwrap();
        assert_eq!(from_toml, from_cli, "TOML job file must parse to the identical request");
    }
}

/// Stratified sweeps keep the plain unweighted output shape (the lead
/// Kronecker point replaces only the first draw) and cut the calibration
/// cell's error to the low-discrepancy O(log N / N) scale, far below the
/// ~0.011 Monte-Carlo standard error at N = 2000.
#[test]
fn stratified_draws_preserve_calibration_end_to_end() {
    let dir = test_dir("strat");
    let service = ArbiterService::new(Backend::Rust, 2);
    let job = JobRequest::Sweep {
        axis: ConfigAxis::GridOffsetNm,
        values: vec![SIGMA],
        thresholds: Some(vec![CAL_TR]),
        measures: vec![Measure::Afp(Policy::LtC)],
        config: ConfigSpec { path: None, inline_toml: Some(planted_toml()), permuted: false },
        options: JobOptions {
            lasers: Some(2000),
            rows: Some(1),
            seed: Some(9),
            out: Some(dir.display().to_string()),
            estimator: Some("stratified".to_string()),
            ..JobOptions::default()
        },
    };
    let resp = service.submit(&job);
    assert!(resp.ok, "{:?}", resp.error);
    let Panel::Grid { cells, stats: Some(stats), .. } = &resp.panels[0] else {
        panic!("stratified sweep keeps the plain grid panel shape");
    };
    assert!(
        (cells[0] - 0.5).abs() < 0.01,
        "Kronecker lead draws must beat the 0.011 MC standard error, got {}",
        cells[0]
    );
    assert_eq!(stats.n_trials[0], 2000);
    let json = Json::parse(&std::fs::read_to_string(dir.join("sweep.json")).unwrap()).unwrap();
    let est = json.get("estimator").expect("estimator metadata recorded");
    assert_eq!(est.get("kind").unwrap().as_str(), Some("stratified"));
    std::fs::remove_dir_all(dir).ok();
}

/// Weighted (importance-tilted) sweeps shard across a real TCP worker
/// fleet: the estimator design rides the inline config TOML in each
/// column envelope, and the merged estimator grids — point estimates,
/// intervals, and trial counts for both AFP and CAFP measures — are
/// bit-identical to a single-node `run_sweep`.
#[test]
fn importance_sweep_is_bit_identical_through_a_worker_fleet() {
    let mut base = planted_config();
    base.scenario.sampling.tilt = TILT;
    let spec = SweepSpec::new("rare-fleet", base, ConfigAxis::GridOffsetNm, vec![0.4, SIGMA])
        .thresholds(vec![CAL_TR, PLANTED_TR])
        .measures([Measure::Afp(Policy::LtC), Measure::Cafp(Scheme::VtRsSsm)]);
    let opts = RunOptions { n_lasers: 32, n_rows: 4, threads: 1, ..RunOptions::fast() };

    let token = CancelToken::new();
    let reference = run_sweep(&spec, &opts, &Backend::Rust, None, &token, &mut |_| {})
        .expect("single-node reference sweep");

    let workers: Vec<WorkerHarness> = (0..2)
        .map(|_| WorkerHarness::spawn(Backend::Rust, 1).expect("spawn in-process worker"))
        .collect();
    let mut fs = FleetSpec::new(workers.iter().map(|w| w.addr()).collect());
    fs.connect_timeout = Duration::from_millis(500);
    fs.io_timeout = Duration::from_millis(200);
    fs.max_probes = 50;
    fs.max_reconnects = 2;
    fs.backoff_base = Duration::from_millis(10);
    let fleet = FleetEvaluator::new(fs);
    let cancel = CancelToken::new();
    let run = fleet
        .run(&spec, &opts, &Backend::Rust, None, &cancel, &mut |_| {})
        .expect("fleet sweep")
        .expect("fleet must not defer to local when workers exist");

    assert_eq!(run.outputs.len(), reference.outputs.len());
    for (got, want) in run.outputs.iter().zip(&reference.outputs) {
        let (SweepOutput::EstGrid { grid: ga, cells: ca }, SweepOutput::EstGrid { grid: gb, cells: cb }) =
            (got, want)
        else {
            panic!("tilted sweeps must produce estimator grids on both paths");
        };
        assert_eq!(ga.x, gb.x);
        assert_eq!(ga.y, gb.y);
        assert_eq!(ca.len(), cb.len());
        for (p, q) in ga.cells.iter().zip(&gb.cells) {
            assert_eq!(p.to_bits(), q.to_bits(), "fleet-merged cell drifted");
        }
        for (x, y) in ca.iter().zip(cb) {
            assert_eq!(x.n_trials, y.n_trials);
            for (p, q) in [(x.p, y.p), (x.lo, y.lo), (x.hi, y.hi)] {
                assert_eq!(p.to_bits(), q.to_bits(), "fleet-merged interval drifted");
            }
        }
    }
}

/// Adaptive splitting on the planted config. The ladder's Gibbs move
/// redraws whole devices, so on this deliberately one-dimensional margin
/// its acceptance rate *equals* the remaining tail probability — clone
/// diversity dies out near ~1e-3 and the deep-1e-6 regime belongs to the
/// IS test above. A 1e-2 plant exercises the full ladder (≈7 median
/// stages) while the closed form still holds: tr = σ(1 − 1e-2).
#[test]
fn splitting_estimates_a_planted_tail() {
    let cfg = planted_config();
    let truth = 1.0e-2;
    let tr = SIGMA * (1.0 - truth);
    let mut covered = 0usize;
    for seed in [3u64, 5, 8] {
        let cell = splitting_afp(&cfg, Policy::LtC, tr, 1000, 30, seed);
        assert!(
            (3.0e-3..3.0e-2).contains(&cell.p),
            "seed {seed}: splitting estimate {} too far from planted {truth}",
            cell.p
        );
        assert!(cell.n_trials >= 1000, "at least the initial particle cloud was evaluated");
        assert!(0.0 < cell.lo && cell.lo <= cell.p && cell.p <= cell.hi);
        if cell.lo <= truth && truth <= cell.hi {
            covered += 1;
        }
        // Pure function of (cfg, seed): a second run is bit-identical.
        let again = splitting_afp(&cfg, Policy::LtC, tr, 1000, 30, seed);
        assert_eq!(cell.p.to_bits(), again.p.to_bits());
        assert_eq!((cell.n_trials, cell.lo.to_bits(), cell.hi.to_bits()),
                   (again.n_trials, again.lo.to_bits(), again.hi.to_bits()));
    }
    assert!(covered >= 2, "log-normal CI must cover the plant for >=2/3 seeds, got {covered}");
}
