//! Fleet end-to-end tests: a real coordinator talking TCP to in-process
//! [`WorkerHarness`] nodes, asserting the ISSUE's core acceptance
//! criterion — fleet-merged panels are **bit-identical** to a single-node
//! run for fleet sizes {1, 2, 4}, including with a worker killed
//! mid-sweep and its columns re-issued to survivors — plus cancellation
//! (no partial panels), the fingerprint guard, and the cache-key exchange.

use std::sync::Arc;
use std::time::Duration;

use wdm_arbiter::api::{
    ArbiterService, ChannelSink, ConfigSpec, JobEvent, JobOptions, JobRequest,
};
use wdm_arbiter::arbiter::Policy;
use wdm_arbiter::config::SystemConfig;
use wdm_arbiter::coordinator::sweep::{ConfigAxis, Measure, SweepOutput, SweepSpec};
use wdm_arbiter::coordinator::{Backend, RunOptions};
use wdm_arbiter::fleet::harness::WorkerHarness;
use wdm_arbiter::fleet::{FleetEvaluator, FleetSpec};
use wdm_arbiter::montecarlo::scheduler::run_sweep;
use wdm_arbiter::montecarlo::{CancelToken, ColumnProgress, RemoteColumns, SWEEP_CANCELED};
use wdm_arbiter::oblivious::Scheme;
use wdm_arbiter::util::json::Json;

const GOLDEN_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden_digests.json");

/// FNV-1a 64-bit over a byte stream (duplicated from `tests/golden.rs`;
/// integration test binaries cannot share code).
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn push(&mut self, byte: u8) {
        self.0 ^= byte as u64;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    fn f64s(&mut self, xs: &[f64]) {
        for x in xs {
            for b in x.to_bits().to_le_bytes() {
                self.push(b);
            }
        }
    }

    fn u64(&mut self, x: u64) {
        for b in x.to_le_bytes() {
            self.push(b);
        }
    }

    fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

/// Stable digest of one sweep output — the same scheme `tests/golden.rs`
/// pins, so a fleet digest is directly comparable to the golden file.
fn output_digest(out: &SweepOutput) -> String {
    let mut h = Fnv::new();
    match out {
        SweepOutput::Curve(series) => {
            h.u64(1);
            h.f64s(&series.x);
            h.f64s(&series.y);
        }
        SweepOutput::Grid(shmoo) => {
            h.u64(2);
            h.f64s(&shmoo.x);
            h.f64s(&shmoo.y);
            h.f64s(&shmoo.cells);
        }
        SweepOutput::CafpGrid { cafp, tallies } => {
            h.u64(3);
            h.f64s(&cafp.x);
            h.f64s(&cafp.y);
            h.f64s(&cafp.cells);
            for t in tallies {
                h.u64(t.trials as u64);
                h.u64(t.policy_failures as u64);
                h.u64(t.conditional_failures as u64);
                h.u64(t.lock_errors as u64);
                h.u64(t.lane_order_errors as u64);
            }
        }
        SweepOutput::EstGrid { grid, cells } => {
            h.u64(4);
            h.f64s(&grid.x);
            h.f64s(&grid.y);
            h.f64s(&grid.cells);
            for c in cells {
                h.u64(c.n_trials as u64);
                h.f64s(&[c.p, c.lo, c.hi]);
            }
        }
    }
    h.hex()
}

fn digests(spec: &SweepSpec, outputs: &[SweepOutput]) -> Vec<(String, String)> {
    spec.measures
        .iter()
        .zip(outputs)
        .map(|(m, o)| (format!("{}/{}", spec.tag, m.slug()), output_digest(o)))
        .collect()
}

/// Eight columns across all three output kinds (curve, grid, CAFP grid
/// with tallies), so the merge path is exercised for every wire shape.
fn wide_spec() -> SweepSpec {
    SweepSpec::new(
        "fleet-wide",
        SystemConfig::default(),
        ConfigAxis::RingLocalNm,
        (0..8).map(|i| 0.56 * (i + 1) as f64).collect(),
    )
    .thresholds(vec![2.0, 6.0, 9.0])
    .measures([
        Measure::Afp(Policy::LtC),
        Measure::MinTrComplete(Policy::LtA),
        Measure::Cafp(Scheme::VtRsSsm),
    ])
}

/// The golden suite's fig4 panel, so fleet digests can be checked against
/// `tests/golden_digests.json` pins when those are blessed.
fn fig4_spec() -> SweepSpec {
    SweepSpec::new(
        "fig4",
        SystemConfig::default(),
        ConfigAxis::RingLocalNm,
        vec![1.12, 2.24, 4.48],
    )
    .thresholds(vec![2.0, 4.0, 6.0, 9.0])
    .measures([Measure::Afp(Policy::LtA), Measure::Afp(Policy::LtC), Measure::Afp(Policy::LtD)])
}

fn opts8() -> RunOptions {
    RunOptions { n_lasers: 8, n_rows: 8, threads: 1, ..RunOptions::fast() }
}

/// Failure-path knobs in milliseconds so dead-worker tests don't stall.
fn test_fleet(workers: Vec<String>) -> FleetSpec {
    let mut fs = FleetSpec::new(workers);
    fs.connect_timeout = Duration::from_millis(500);
    fs.io_timeout = Duration::from_millis(200);
    fs.max_probes = 50;
    fs.max_reconnects = 2;
    fs.backoff_base = Duration::from_millis(10);
    fs
}

fn spawn_workers(n: usize) -> Vec<WorkerHarness> {
    (0..n)
        .map(|_| WorkerHarness::spawn(Backend::Rust, 1).expect("spawn in-process worker"))
        .collect()
}

fn local_reference(spec: &SweepSpec, opts: &RunOptions) -> Vec<(String, String)> {
    let token = CancelToken::new();
    let run = run_sweep(spec, opts, &Backend::Rust, None, &token, &mut |_| {})
        .expect("single-node reference sweep");
    digests(spec, &run.outputs)
}

fn golden_pins() -> Vec<(String, String)> {
    let Ok(text) = std::fs::read_to_string(GOLDEN_PATH) else { return Vec::new() };
    let Ok(Json::Obj(pairs)) = Json::parse(&text) else { return Vec::new() };
    pairs
        .into_iter()
        .filter_map(|(k, v)| v.as_str().map(|s| (k, s.to_string())))
        .collect()
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("wdm-fleet-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create temp out dir");
    dir
}

/// The acceptance criterion: for fleet sizes {1, 2, 4}, the fleet-merged
/// panel digests equal a single-node `run_sweep`'s, bit for bit, and
/// fig4 additionally matches any blessed golden pins.
#[test]
fn fleet_panels_bit_identical_across_fleet_sizes() {
    let opts = opts8();
    let pins = golden_pins();
    for spec in [wide_spec(), fig4_spec()] {
        let reference = local_reference(&spec, &opts);
        for fleet_size in [1usize, 2, 4] {
            let workers = spawn_workers(fleet_size);
            let addrs = workers.iter().map(|w| w.addr()).collect();
            let fleet = FleetEvaluator::new(test_fleet(addrs));
            let cancel = CancelToken::new();
            let run = fleet
                .run(&spec, &opts, &Backend::Rust, None, &cancel, &mut |_| {})
                .expect("fleet sweep")
                .expect("fleet must not defer to local when workers exist");
            assert_eq!(
                digests(&spec, &run.outputs),
                reference,
                "{}: fleet of {fleet_size} drifted from the single-node panel",
                spec.tag
            );
            assert_eq!(run.backend, "rust-f64", "uniform rust workers report their backend");

            let stats = fleet.last_run_stats().expect("completed run records stats");
            assert_eq!(stats.n_cols, spec.values.len());
            assert_eq!(stats.local_columns, 0, "healthy fleet never runs columns locally");
            let served: usize = stats.workers.iter().map(|w| w.columns).sum();
            assert_eq!(served, spec.values.len());
            assert!(stats.workers.iter().all(|w| w.alive));
            // A worker only connects when it pops a column, so only those
            // that served anything have handshaken (fleets larger than the
            // column count leave idle workers unconnected).
            assert!(
                stats.workers.iter().filter(|w| w.columns > 0).all(|w| !w.release.is_empty()),
                "handshake records each serving worker's release"
            );
        }
        // Same digest scheme as tests/golden.rs: when fig4 pins are
        // blessed, the fleet panels must match them too.
        for (name, digest) in &reference {
            if let Some((_, pinned)) = pins.iter().find(|(k, _)| k == name) {
                assert_eq!(digest, pinned, "panel '{name}' drifted from its golden pin");
            }
        }
    }
}

/// Kill one of two workers from the first progress callback — its
/// in-flight column must be re-issued to the survivor and the merged
/// panel must still be byte-identical to the single-node reference.
#[test]
fn killed_worker_mid_sweep_reissues_columns_and_stays_bit_identical() {
    let spec = wide_spec();
    let opts = opts8();
    let reference = local_reference(&spec, &opts);

    let mut workers = spawn_workers(2);
    let addrs = workers.iter().map(|w| w.addr()).collect();
    let mut victim = Some(workers.remove(0));
    let fleet = FleetEvaluator::new(test_fleet(addrs));
    let cancel = CancelToken::new();
    let mut on_col = |_p: ColumnProgress| {
        // First merged column: hard-stop worker 0 (connections severed
        // mid-write, listener gone — a crashed node, not a drained one).
        if let Some(mut w) = victim.take() {
            w.kill();
        }
    };
    let run = fleet
        .run(&spec, &opts, &Backend::Rust, None, &cancel, &mut on_col)
        .expect("sweep must survive losing one of two workers")
        .expect("fleet ran remotely");

    assert_eq!(
        digests(&spec, &run.outputs),
        reference,
        "panel after mid-sweep worker loss must be bit-identical to single-node"
    );
    let stats = fleet.last_run_stats().expect("stats recorded");
    assert_eq!(stats.n_cols, spec.values.len());
    let served: usize = stats.workers.iter().map(|w| w.columns).sum();
    assert_eq!(
        served + stats.local_columns,
        spec.values.len(),
        "every column accounted to a worker (no local fallback was configured)"
    );
    assert_eq!(stats.local_columns, 0);
    assert!(stats.workers[1].alive, "the survivor stays usable");
}

/// Cancellation: the run reports `SWEEP_CANCELED` with no partial panels —
/// both at the evaluator layer and through the service (no `sweep.json`).
#[test]
fn cancel_mid_fleet_leaves_no_partial_panels() {
    // Evaluator layer: fire the token from the first progress callback.
    let spec = wide_spec();
    let opts = opts8();
    let workers = spawn_workers(1);
    let fleet = FleetEvaluator::new(test_fleet(vec![workers[0].addr()]));
    let cancel = CancelToken::new();
    let mut on_col = |_p: ColumnProgress| cancel.cancel();
    let err = fleet
        .run(&spec, &opts, &Backend::Rust, None, &cancel, &mut on_col)
        .expect_err("canceled sweep must not return a panel");
    assert_eq!(err, SWEEP_CANCELED);
    assert!(fleet.last_run_stats().is_none(), "canceled runs record no stats");

    // Service layer: cancel the job handle after the first ColumnDone
    // event; the response is canceled and no sweep.json was written.
    let out = tmp_dir("cancel");
    let workers = spawn_workers(1);
    let service = ArbiterService::new(Backend::Rust, 1)
        .with_fleet(FleetEvaluator::new(test_fleet(vec![workers[0].addr()])));
    let req = JobRequest::Sweep {
        axis: ConfigAxis::RingLocalNm,
        values: (0..16).map(|i| 0.28 * (i + 1) as f64).collect(),
        thresholds: None,
        measures: vec![Measure::MinTrComplete(Policy::LtC)],
        config: ConfigSpec::default(),
        options: JobOptions {
            out: Some(out.display().to_string()),
            fast: true,
            lasers: Some(12),
            rows: Some(12),
            threads: Some(1),
            seed: Some(7),
            ..JobOptions::default()
        },
    };
    let (sink, rx) = ChannelSink::pair();
    let handle = service.submit_async_with(req, Arc::new(sink));
    loop {
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(JobEvent::ColumnDone { .. }) => break,
            Ok(_) => continue,
            Err(e) => panic!("no ColumnDone before cancel: {e}"),
        }
    }
    handle.cancel();
    let resp = handle.wait();
    assert!(resp.canceled, "response must report cancellation");
    assert!(!resp.ok);
    assert!(!out.join("sweep.json").exists(), "canceled sweeps write no partial panel");
    let _ = std::fs::remove_dir_all(&out);
}

/// The fingerprint guard: a column job whose config digest disagrees with
/// the worker's resolved config fails structurally instead of evaluating.
#[test]
fn mismatched_fingerprint_fails_structurally() {
    let service = ArbiterService::new(Backend::Rust, 1);
    let column = |fingerprint: &str| JobRequest::Column {
        tag: "fig4".to_string(),
        lane: 0,
        axis: ConfigAxis::RingLocalNm,
        values: vec![1.12, 2.24],
        ix: 0,
        thresholds: vec![2.0, 6.0],
        measures: vec![Measure::Afp(Policy::LtC)],
        config: ConfigSpec::default(),
        seed: 42,
        lasers: 4,
        rows: 4,
        fingerprint: fingerprint.to_string(),
    };
    let bad = service.submit(&column("00000000deadbeef"));
    assert!(!bad.ok);
    assert!(
        bad.error.as_deref().unwrap_or("").contains("fingerprint mismatch"),
        "got error: {:?}",
        bad.error
    );
    // Empty fingerprint skips the check; the job evaluates.
    let good = service.submit(&column(""));
    assert!(good.ok, "got error: {:?}", good.error);
}

/// The cache-key exchange: re-running the same sweep against the same
/// worker reports population-cache hits back through the column
/// responses into the coordinator's per-worker stats.
#[test]
fn worker_population_caches_hit_on_repeat_sweeps() {
    let spec = wide_spec();
    let opts = opts8();
    let reference = local_reference(&spec, &opts);
    let workers = spawn_workers(1);
    let fleet = FleetEvaluator::new(test_fleet(vec![workers[0].addr()]));

    let run_once = || {
        let cancel = CancelToken::new();
        let run = fleet
            .run(&spec, &opts, &Backend::Rust, None, &cancel, &mut |_| {})
            .expect("fleet sweep")
            .expect("ran remotely");
        assert_eq!(digests(&spec, &run.outputs), reference);
        fleet.last_run_stats().expect("stats recorded")
    };
    let first = run_once();
    let second = run_once();

    let n_cols = spec.values.len();
    assert!(
        first.workers[0].cache_misses >= n_cols,
        "first run populates: {} misses",
        first.workers[0].cache_misses
    );
    assert!(
        second.workers[0].cache_hits >= n_cols,
        "second run hits the worker's population cache: {} hits",
        second.workers[0].cache_hits
    );
    assert_eq!(second.workers[0].cache_misses, 0, "identical sweep re-misses nothing");
}

/// Through the service, a fleet-dispatched sweep writes a `sweep.json`
/// byte-identical to a local service's, while the response carries the
/// fleet bookkeeping (which never touches the artifact).
#[test]
fn fleet_sweep_json_is_byte_identical_to_local() {
    let req = |out: &std::path::Path| JobRequest::Sweep {
        axis: ConfigAxis::RingLocalNm,
        values: vec![1.12, 2.24, 4.48],
        thresholds: Some(vec![2.0, 6.0]),
        measures: vec![Measure::Afp(Policy::LtC)],
        config: ConfigSpec::default(),
        options: JobOptions {
            out: Some(out.display().to_string()),
            fast: true,
            lasers: Some(8),
            rows: Some(8),
            threads: Some(1),
            ..JobOptions::default()
        },
    };

    let solo_dir = tmp_dir("solo");
    let solo = ArbiterService::new(Backend::Rust, 1).submit(&req(&solo_dir));
    assert!(solo.ok, "local sweep failed: {:?}", solo.error);

    let fleet_dir = tmp_dir("fleet");
    let workers = spawn_workers(2);
    let addrs = workers.iter().map(|w| w.addr()).collect();
    let service = ArbiterService::new(Backend::Rust, 1)
        .with_fleet(FleetEvaluator::new(test_fleet(addrs)));
    let fleet = service.submit(&req(&fleet_dir));
    assert!(fleet.ok, "fleet sweep failed: {:?}", fleet.error);

    let solo_bytes = std::fs::read(solo_dir.join("sweep.json")).expect("solo sweep.json");
    let fleet_bytes = std::fs::read(fleet_dir.join("sweep.json")).expect("fleet sweep.json");
    assert_eq!(solo_bytes, fleet_bytes, "fleet sweep.json must be byte-identical to local");

    assert!(fleet.data.get("fleet").is_some(), "response data carries fleet bookkeeping");
    assert!(fleet.summary.contains("fleet:"), "summary names the fleet: {}", fleet.summary);
    assert!(solo.data.get("fleet").is_none(), "local runs report no fleet");
    let _ = std::fs::remove_dir_all(&solo_dir);
    let _ = std::fs::remove_dir_all(&fleet_dir);
}
