//! Job-API acceptance contract: every CLI invocation maps to a
//! [`JobRequest`] that serializes to JSON and parses back identical, and
//! the TOML job-file form agrees with the JSON form.

use wdm_arbiter::api::cli::job_from_args;
use wdm_arbiter::api::JobRequest;
use wdm_arbiter::util::cli::Args;

fn args(s: &[&str]) -> Args {
    let v: Vec<String> = s.iter().map(|x| x.to_string()).collect();
    Args::parse(&v, &["fast", "cases", "permuted", "help"]).unwrap()
}

#[test]
fn every_cli_invocation_round_trips_through_json() {
    let invocations: Vec<Vec<&str>> = vec![
        // run — plain, fully-flagged, xla backend, and `run all`.
        vec!["run", "table1"],
        vec![
            "run", "fig4", "--out", "out", "--fast", "--lasers", "4", "--rows", "5", "--seed",
            "7", "--threads", "2", "--backend", "rust",
        ],
        vec!["run", "fig14", "--backend", "xla"],
        vec!["run", "all", "--fast", "--out", "results"],
        // sweep — list and range values, every measure kind, config flags.
        vec![
            "sweep", "--axis", "ring-local", "--values", "0.28:8.96:0.56", "--measure",
            "afp:ltc,cafp:vt-rs-ssm", "--fast",
        ],
        vec![
            "sweep", "--axis", "grid-offset", "--values", "0,5,10", "--tr", "2:9:1",
            "--measure", "min-tr:lta,alias-min-tr:ltc", "--config", "cfg.toml", "--permuted",
            "--seed", "3",
        ],
        vec!["sweep", "--axis", "channels", "--values", "4,8,16"],
        // adaptive allocation + scheduler knobs
        vec![
            "sweep", "--axis", "ring-local", "--values", "1.12,2.24", "--tr", "2,6",
            "--measure", "cafp:vt-rs-ssm", "--ci", "0.01", "--min-trials", "200",
            "--max-trials", "10000", "--inflight", "4", "--threads", "8",
        ],
        vec!["sweep", "--axis", "permuted", "--values", "0,1", "--measure", "cafp:seq"],
        vec!["sweep", "--axis", "fsr-mean", "--values", "7:11:0.5", "--measure", "min-tr:ltc"],
        // arbitrate — defaults, every flag, each scheme alias.
        vec!["arbitrate"],
        vec!["arbitrate", "--scheme", "rs-ssm", "--tr", "5.5", "--seed", "123", "--permuted"],
        vec!["arbitrate", "--scheme", "seq", "--config", "cfg.toml"],
        // show-config — plain and with cases + config.
        vec!["show-config"],
        vec!["show-config", "--cases", "--config", "cfg.toml", "--permuted"],
    ];
    for argv in invocations {
        let job = job_from_args(&args(&argv)).unwrap_or_else(|e| panic!("{argv:?}: {e}"));
        let json = job.to_json_string();
        let back = JobRequest::from_json_str(&json)
            .unwrap_or_else(|e| panic!("{argv:?}: {e} while re-parsing {json}"));
        assert_eq!(back, job, "{argv:?} failed to round-trip through {json}");
    }
}

#[test]
fn run_all_maps_to_a_batch_that_round_trips() {
    let job = job_from_args(&args(&["run", "all", "--fast", "--seed", "11"])).unwrap();
    let JobRequest::Batch { jobs } = &job else { panic!("run all must map to a batch") };
    assert!(jobs.len() >= 10, "all paper experiments present");
    let back = JobRequest::from_json_str(&job.to_json_string()).unwrap();
    assert_eq!(back, job);
}

#[test]
fn toml_job_file_agrees_with_cli_mapping() {
    let from_cli = job_from_args(&args(&[
        "sweep", "--axis", "ring-local", "--values", "1.12,2.24", "--tr", "2,6", "--measure",
        "afp:ltc", "--fast",
    ]))
    .unwrap();
    let from_toml = JobRequest::from_toml(
        r#"
[job]
type = "sweep"
axis = "ring-local"
values = [1.12, 2.24]
tr = [2.0, 6.0]
measures = "afp:ltc"
[job.options]
fast = true
"#,
    )
    .unwrap();
    assert_eq!(from_cli, from_toml);
}
