//! Paper-shape regression tests: the qualitative results of the paper's
//! evaluation section, asserted at reduced Monte-Carlo scale. These are the
//! "does the reproduction actually reproduce" tests; EXPERIMENTS.md records
//! the full-scale runs.

use wdm_arbiter::arbiter::Policy;
use wdm_arbiter::config::SystemConfig;
use wdm_arbiter::model::system::SystemSampler;
use wdm_arbiter::montecarlo::sweep::{unit_multiples, Series};
use wdm_arbiter::montecarlo::{cafp_tally, min_tr_complete, IdealEvaluator, RustIdeal};
use wdm_arbiter::oblivious::Scheme;

const SIDE: usize = 20; // 400 trials/point: enough for shape-level checks

fn min_tr_series(policy: Policy, edit: impl Fn(&mut SystemConfig, f64), values: &[f64], seed: u64) -> Series {
    let eval = RustIdeal::default();
    let y: Vec<f64> = values
        .iter()
        .enumerate()
        .map(|(i, &v)| {
            let mut cfg = SystemConfig::default();
            edit(&mut cfg, v);
            let sampler = SystemSampler::new(&cfg, SIDE, SIDE, seed + i as u64);
            min_tr_complete(&eval.min_trs(&cfg, &sampler, policy))
        })
        .collect();
    Series::new(format!("{policy}"), values.to_vec(), y)
}

/// Fig 5: LtC pre-saturation ramp slope ≈ 2 vs σ_rLV. (LtA's ramp flattens
/// earlier — the paper notes its "slower ramp beyond ~3·λ_gS" — so the
/// clean slope-2 claim is asserted on LtC; measured ≈ 1.98 at this scale.)
#[test]
fn fig5_ramp_slope_is_about_two() {
    let values = unit_multiples(1.12, 0.25, 2.0, 0.25);
    let s = min_tr_series(Policy::LtC, |c, v| c.variation.ring_local_nm = v, &values, 100);
    let slope = s.slope();
    assert!(
        (1.5..=2.5).contains(&slope),
        "LtC ramp slope {slope} outside [1.5, 2.5]"
    );
}

/// Fig 5: LtC saturates at about the FSR once σ_rLV is large.
#[test]
fn fig5_ltc_saturates_at_fsr() {
    let cfg = SystemConfig::default();
    let values = vec![8.0 * 1.12];
    let s = min_tr_series(Policy::LtC, |c, v| c.variation.ring_local_nm = v, &values, 200);
    for &y in &s.y {
        // Scaled by TR variation the ceiling is FSR / 0.9 ≈ 9.96; at this
        // sampling scale (400 trials/point) the max sits slightly below it.
        assert!(y <= cfg.fsr_mean_nm / 0.85, "LtC min TR {y} beyond FSR ceiling");
        assert!(y >= 0.85 * cfg.fsr_mean_nm, "LtC min TR {y} below saturation");
    }
}

/// Fig 4/5: LtA needs no more tuning range than LtC anywhere.
#[test]
fn fig5_lta_never_worse_than_ltc() {
    let values = unit_multiples(1.12, 0.5, 8.0, 1.5);
    let lta = min_tr_series(Policy::LtA, |c, v| c.variation.ring_local_nm = v, &values, 300);
    let ltc = min_tr_series(Policy::LtC, |c, v| c.variation.ring_local_nm = v, &values, 300);
    for i in 0..values.len() {
        assert!(lta.y[i] <= ltc.y[i] + 1e-9, "sigma {}: LtA {} > LtC {}", values[i], lta.y[i], ltc.y[i]);
    }
}

/// Fig 6: LtD at zero grid offset ramps with slope ≈ 1 in σ_rLV.
#[test]
fn fig6_ltd_slope_about_one_at_zero_offset() {
    let values = unit_multiples(1.12, 0.25, 2.5, 0.25);
    let s = min_tr_series(
        Policy::LtD,
        |c, v| {
            c.variation.grid_offset_nm = 0.0;
            c.variation.ring_local_nm = v;
        },
        &values,
        400,
    );
    let slope = s.slope();
    assert!((0.7..=1.3).contains(&slope), "LtD slope {slope} outside [0.7, 1.3]");
}

/// Fig 6: large grid offsets pin LtD's requirement near the FSR.
#[test]
fn fig6_large_offset_pins_ltd_at_fsr() {
    let cfg = SystemConfig::default();
    let s = min_tr_series(
        Policy::LtD,
        |c, v| {
            c.variation.grid_offset_nm = 7.0;
            c.variation.ring_local_nm = v;
        },
        &[0.28, 2.24],
        500,
    );
    for &y in &s.y {
        assert!(y > 0.85 * cfg.fsr_mean_nm, "LtD with 7nm offset should be near FSR, got {y}");
    }
}

/// Fig 7(b): minimum-TR sensitivity to laser local variation for LtC.
/// The paper measures ≈ 0.56 nm per 25 % at 10k trials/point; the max-over-
/// trials statistic converges slowly from below (joint extremes of ring and
/// laser draws must both be sampled), so at 2.5k trials we assert the
/// direction and a converging magnitude (measured ≈ 0.38 here).
#[test]
fn fig7_laser_local_sensitivity() {
    let eval = RustIdeal::default();
    let values = [0.05, 0.15, 0.25, 0.35, 0.45];
    let y: Vec<f64> = values
        .iter()
        .enumerate()
        .map(|(i, &v)| {
            let mut cfg = SystemConfig::default();
            cfg.variation.ring_local_nm = 2.24;
            cfg.variation.laser_local_frac = v;
            let sampler = SystemSampler::new(&cfg, 50, 50, 600 + i as u64);
            min_tr_complete(&eval.min_trs(&cfg, &sampler, Policy::LtC))
        })
        .collect();
    let s = Series::new("LtC", values.to_vec(), y);
    let per25 = s.slope() * 0.25;
    assert!(
        (0.15..=0.9).contains(&per25),
        "dminTR/dsigma_lLV = {per25} nm/25% outside [0.15, 0.9] (paper ~0.56 at 10k trials)"
    );
}

/// Fig 7(a): grid offset beyond one grid spacing does not change LtC's
/// requirement (cyclic re-centering).
#[test]
fn fig7_offset_flat_for_ltc() {
    let s = min_tr_series(
        Policy::LtC,
        |c, v| {
            c.variation.ring_local_nm = 2.24;
            c.variation.grid_offset_nm = v;
        },
        &[2.0, 8.0, 15.0],
        700,
    );
    let spread = s.y.iter().cloned().fold(f64::MIN, f64::max)
        - s.y.iter().cloned().fold(f64::MAX, f64::min);
    assert!(spread < 0.8, "LtC min TR should be flat in grid offset, spread {spread}");
}

/// Fig 8: under-designing the FSR well below N·λ_gS makes complete success
/// unreachable through resonance aliasing (a microring comb landing on two
/// laser tones), while over-design degrades gradually. Uses the
/// alias-aware evaluation (see arbiter::distance).
#[test]
fn fig8_fsr_underdesign_penalty() {
    use wdm_arbiter::arbiter::distance::ALIAS_EPS_NM;
    use wdm_arbiter::montecarlo::alias_aware_min_trs;
    let nominal = 8.96;
    let at = |fsr: f64, seed: u64| {
        let mut cfg = SystemConfig::default();
        cfg.fsr_mean_nm = fsr;
        let sampler = SystemSampler::new(&cfg, SIDE, SIDE, seed);
        min_tr_complete(&alias_aware_min_trs(&cfg, &sampler, Policy::LtC, ALIAS_EPS_NM, 0))
    };
    let under = at(nominal - 2.24, 800);
    let nom = at(nominal, 801);
    let over = at(nominal + 2.24, 802);
    assert!(nom.is_finite(), "nominal design must be feasible, got {nom}");
    assert!(
        under > nom + 1.0,
        "under-design must cost sharply: {under} vs {nom}"
    );
    assert!(over > nom - 0.3, "over-design should not help: {over} vs {nom}");
    assert!(over.is_finite(), "over-design stays feasible (no aliasing in span)");
}

/// Fig 14: scheme ranking seq >> rs-ssm >= vt-rs-ssm ≈ 0 at a
/// representative operating point.
#[test]
fn fig14_scheme_ranking_at_6nm() {
    let cfg = SystemConfig::default();
    let seq = cafp_tally(&cfg, Scheme::Sequential, 6.0, SIDE, SIDE, 900, 0);
    let rs = cafp_tally(&cfg, Scheme::RsSsm, 6.0, SIDE, SIDE, 900, 0);
    let vt = cafp_tally(&cfg, Scheme::VtRsSsm, 6.0, SIDE, SIDE, 900, 0);
    assert!(seq.cafp() > 0.5, "sequential should fail often, got {}", seq.cafp());
    assert!(rs.cafp() < 0.1, "rs-ssm should be small, got {}", rs.cafp());
    assert!(vt.cafp() < 0.005, "vt-rs-ssm should be ~0, got {}", vt.cafp());
}

/// Fig 15: above the FSR, sequential failures are exclusively lane-order
/// errors (every tone is reachable, so locks always complete); below it,
/// the scheme shows *significant* zero/duplicate lock errors even under
/// ideal laser/FSR/TR variations (the paper's §V-D claim).
#[test]
fn fig15_error_composition_flips_at_fsr() {
    use wdm_arbiter::model::VariationConfig;
    let mut ideal_cfg = SystemConfig::default();
    ideal_cfg.variation = VariationConfig::ideal_fig15(2.24);
    let below = cafp_tally(&ideal_cfg, Scheme::Sequential, 6.0, SIDE, SIDE, 1000, 0);
    assert!(
        below.lock_errors as f64 > 0.05 * below.trials as f64,
        "below FSR lock errors should be significant even under ideal variations: {below:?}"
    );
    let cfg = SystemConfig::default();
    let above = cafp_tally(&cfg, Scheme::Sequential, 10.08, SIDE, SIDE, 1000, 0);
    assert!(
        above.lane_order_errors >= above.lock_errors,
        "above FSR lane-order should dominate: {above:?}"
    );
    assert!(
        above.lane_order_errors as f64 > 0.5 * above.conditional_failures as f64,
        "above FSR lane-order should be the majority failure: {above:?}"
    );
}

/// Fig 16: under harsh σ_FSR/σ_TR, VT-RS/SSM stays no worse than RS/SSM.
#[test]
fn fig16_vt_no_worse_under_harsh_variation() {
    let mut cfg = SystemConfig::default();
    cfg.variation.fsr_frac = 0.05;
    cfg.variation.tr_frac = 0.20;
    for tr in [3.0, 8.0] {
        let rs = cafp_tally(&cfg, Scheme::RsSsm, tr, SIDE, SIDE, 1100, 0);
        let vt = cafp_tally(&cfg, Scheme::VtRsSsm, tr, SIDE, SIDE, 1100, 0);
        assert!(
            vt.cafp() <= rs.cafp() + 1e-9,
            "tr={tr}: vt {} > rs {}",
            vt.cafp(),
            rs.cafp()
        );
    }
}

/// §IV-A: pre-fabrication ordering does not change the ideal minimum
/// tuning range for LtA/LtC (N vs P cases agree within sampling noise).
#[test]
fn fig5_natural_vs_permuted_agree() {
    let eval = RustIdeal::default();
    for policy in [Policy::LtA, Policy::LtC] {
        let mut vals = Vec::new();
        for permuted in [false, true] {
            let mut cfg = SystemConfig::default();
            if permuted {
                cfg = cfg.with_permuted_orders();
            }
            cfg.variation.ring_local_nm = 2.24;
            let sampler = SystemSampler::new(&cfg, SIDE, SIDE, 1200);
            vals.push(min_tr_complete(&eval.min_trs(&cfg, &sampler, policy)));
        }
        let diff = (vals[0] - vals[1]).abs();
        assert!(diff < 0.7, "{policy}: N vs P min TR differ by {diff} ({vals:?})");
    }
}
