//! Batched-vs-scalar bit-identity for the **oblivious** kernel: the SoA
//! trial kernel (`oblivious::batch` via `batched_cafp_tally` /
//! `RustOblivious::tally`) must reproduce the per-trial oracle
//! (`run_scheme_with` via `RustOblivious::tally_scalar`) **bit for bit** —
//! per scheme, under every scenario family (including dead tones / dark
//! rings / weak rings), and for any chunk-size / thread-count combination.
//! The ideal-model twin of this contract lives in
//! `tests/batched_equivalence.rs`; together they let both hot paths change
//! shape without moving a single golden digest.

use wdm_arbiter::arbiter::Policy;
use wdm_arbiter::config::SystemConfig;
use wdm_arbiter::model::system::SystemSampler;
use wdm_arbiter::model::{CorrelationConfig, Distribution, FaultsConfig};
use wdm_arbiter::montecarlo::{
    batched_cafp_tally, Population, RustIdeal, RustOblivious, TrialEngine,
};
use wdm_arbiter::oblivious::batch::BatchWorkspace;
use wdm_arbiter::oblivious::{run_scheme_with, Scheme, Workspace};

/// One representative config per scenario family (mirrors
/// `tests/batched_equivalence.rs`): the oblivious pipeline branches
/// differently under faults (empty tables, Null relations, φ-clusters),
/// correlation (shared structure) and non-uniform draws.
fn scenario_configs() -> Vec<(&'static str, SystemConfig)> {
    let mut out = vec![("default", SystemConfig::default())];
    let mut gauss = SystemConfig::default();
    gauss.scenario.distribution = Distribution::by_name("trimmed-gaussian").unwrap();
    out.push(("trimmed-gaussian", gauss));
    let mut bimodal = SystemConfig::default();
    bimodal.scenario.distribution = Distribution::by_name("bimodal").unwrap();
    out.push(("bimodal", bimodal));
    let mut corr = SystemConfig::default();
    corr.scenario.correlation = CorrelationConfig { gradient_nm: 2.0, corr_len: 3.0 };
    out.push(("correlated", corr));
    let mut faulty = SystemConfig::default();
    faulty.scenario.faults = FaultsConfig {
        dead_tone_p: 0.2,
        dark_ring_p: 0.2,
        weak_ring_p: 0.2,
        weak_tr_factor: 0.5,
    };
    out.push(("faulty", faulty));
    out
}

fn population(cfg: &SystemConfig, n_lasers: usize, n_rows: usize, seed: u64) -> Population {
    let ideal = RustIdeal { threads: 1 };
    let engine = TrialEngine::new(&ideal, 1);
    (*engine.population(cfg, n_lasers, n_rows, seed, &[Policy::LtC])).clone()
}

/// The full contract: scheme × scenario × chunk {1, 7, 64, 4096} ×
/// threads {1, 2, 5}, batched CAFP tally equal to the scalar oracle's.
/// Tallies are plain counters, so equality here means every per-trial
/// (gate, class) pair agreed (the per-trial check below pins the classes
/// themselves).
#[test]
fn batched_tally_matches_scalar_across_scenarios_chunks_threads() {
    for (name, cfg) in scenario_configs() {
        let pop = population(&cfg, 9, 11, 2024); // 99 trials: chunks 1/7/64 all refill
        for scheme in Scheme::all() {
            for tr in [2.0, 6.0, 9.0] {
                let scalar = RustOblivious { scheme, threads: 1 }.tally_scalar(&pop, tr);
                for chunk in [1usize, 7, 64, 4096] {
                    for threads in [1usize, 2, 5] {
                        let batched = batched_cafp_tally(&pop, scheme, tr, threads, chunk);
                        assert_eq!(
                            batched,
                            scalar,
                            "{name}/{} tr={tr} chunk={chunk} threads={threads}",
                            scheme.name()
                        );
                    }
                }
            }
        }
    }
}

/// Per-trial classes (ungated, every trial simulated): the batched block
/// runner must classify each trial exactly like the scalar scheme runner —
/// a stronger statement than tally equality, pinned per scenario family.
#[test]
fn run_block_classes_match_scalar_per_trial() {
    for (name, cfg) in scenario_configs() {
        let sampler = SystemSampler::new(&cfg, 7, 8, 31); // 56 trials
        let mut scalar_ws = Workspace::new();
        for scheme in Scheme::all() {
            for tr in [2.0, 6.0] {
                let mut ws = BatchWorkspace::with_chunk(13); // uneven chunking
                let mut got = Vec::new();
                ws.run_block(
                    scheme,
                    &sampler,
                    &cfg.target_order,
                    tr,
                    0..sampler.n_trials(),
                    None,
                    &mut |t, ideal_ok, class| {
                        assert!(ideal_ok, "no gate: every trial runs");
                        got.push((t, class));
                    },
                );
                assert_eq!(got.len(), sampler.n_trials());
                for (t, class) in got {
                    let (laser, rings) = sampler.trial(t);
                    let want =
                        run_scheme_with(scheme, laser, rings, &cfg.target_order, tr, &mut scalar_ws)
                            .class;
                    assert_eq!(
                        class,
                        Some(want),
                        "{name}/{} tr={tr} trial {t}",
                        scheme.name()
                    );
                }
            }
        }
    }
}

/// The scalar oracle itself must not depend on its worker count, otherwise
/// the equivalences above would compare against a moving target.
#[test]
fn scalar_tally_is_thread_invariant() {
    let pop = population(&SystemConfig::default(), 8, 8, 7);
    for scheme in Scheme::all() {
        let one = RustOblivious { scheme, threads: 1 }.tally_scalar(&pop, 6.0);
        let four = RustOblivious { scheme, threads: 4 }.tally_scalar(&pop, 6.0);
        assert_eq!(one, four, "{} scalar threads=4 vs 1", scheme.name());
    }
}

/// Near-certain faults: empty search tables, Null relations everywhere,
/// φ-cluster paths, zero-lock adjudication — the batched kernel's trickiest
/// regime must still be bit-exact, and the gate vector is mostly infinite
/// (so most trials skip the oblivious simulation entirely).
#[test]
fn heavy_fault_populations_stay_exact() {
    let mut cfg = SystemConfig::default();
    cfg.scenario.faults = FaultsConfig {
        dead_tone_p: 0.6,
        dark_ring_p: 0.6,
        weak_ring_p: 0.3,
        weak_tr_factor: 0.5,
    };
    let pop = population(&cfg, 12, 12, 555);
    assert!(
        pop.ideal_ltc().iter().any(|v| v.is_infinite()),
        "regime check: some trials should be unarbitrable at any range"
    );
    for scheme in Scheme::all() {
        for tr in [2.0, 6.0, 12.0] {
            let scalar = RustOblivious { scheme, threads: 2 }.tally_scalar(&pop, tr);
            for chunk in [1usize, 64] {
                let batched = batched_cafp_tally(&pop, scheme, tr, 2, chunk);
                assert_eq!(batched, scalar, "heavy-faults/{} tr={tr} chunk={chunk}", scheme.name());
            }
        }
    }
}

/// Explicit SIMD-tier axis: the batched tally at every tier this host can
/// run (scalar always; AVX2 where detected) equals the scalar oracle bit
/// for bit. This pins cross-tier identity in a *single* process — the CI
/// legs additionally run the whole suite under `WDM_SIMD=scalar` and
/// `WDM_SIMD=auto` to cover the env-dispatch path.
#[test]
fn batched_tally_matches_scalar_at_every_simd_tier() {
    use wdm_arbiter::montecarlo::batched_cafp_tally_tier;
    use wdm_arbiter::util::simd;
    for (name, cfg) in scenario_configs() {
        let pop = population(&cfg, 7, 7, 404);
        for scheme in Scheme::all() {
            for tr in [2.0, 6.0] {
                let scalar = RustOblivious { scheme, threads: 1 }.tally_scalar(&pop, tr);
                for tier in simd::available_tiers() {
                    let batched = batched_cafp_tally_tier(&pop, scheme, tr, 2, 16, tier);
                    assert_eq!(
                        batched,
                        scalar,
                        "{name}/{} tr={tr} tier={tier:?}",
                        scheme.name()
                    );
                }
            }
        }
    }
}

/// >64-channel regression: grids above the former u64 mask ceiling must
/// stay on the batched path (no silent scalar fallback) and remain
/// bit-identical to the oracle — the widened multi-word `ToneMask` at work.
#[test]
fn wide_grids_stay_on_the_batched_path_and_match() {
    use wdm_arbiter::model::DwdmGrid;
    use wdm_arbiter::oblivious::batch::MAX_MASK_CH;
    let cfg = SystemConfig::table1(DwdmGrid { n_ch: 72, spacing_nm: 1.12 });
    assert!(cfg.grid.n_ch > 64, "test must exceed the former single-u64 ceiling");
    assert!(
        cfg.grid.n_ch <= MAX_MASK_CH,
        "test must stay on the batched path (no scalar fallback)"
    );
    let pop = population(&cfg, 3, 3, 4242);
    for scheme in Scheme::all() {
        for tr in [30.0, 60.0] {
            let scalar = RustOblivious { scheme, threads: 1 }.tally_scalar(&pop, tr);
            let batched = batched_cafp_tally(&pop, scheme, tr, 2, 4);
            assert_eq!(batched, scalar, "wide/{} tr={tr}", scheme.name());
        }
    }
    // Per-trial classes too (ungated, every trial simulated): sequential
    // tuning's prefix lock masks and adjudication's seen-mask both cross
    // the word boundary at 72 channels.
    let sampler = SystemSampler::new(&cfg, 2, 2, 77);
    let mut scalar_ws = Workspace::new();
    let mut ws = BatchWorkspace::with_chunk(3);
    for scheme in Scheme::all() {
        let mut got = Vec::new();
        ws.run_block(
            scheme,
            &sampler,
            &cfg.target_order,
            40.0,
            0..sampler.n_trials(),
            None,
            &mut |t, _, class| got.push((t, class.expect("ungated"))),
        );
        assert_eq!(got.len(), sampler.n_trials());
        for (t, class) in got {
            let (laser, rings) = sampler.trial(t);
            let want =
                run_scheme_with(scheme, laser, rings, &cfg.target_order, 40.0, &mut scalar_ws)
                    .class;
            assert_eq!(class, want, "wide/{} trial {t}", scheme.name());
        }
    }
}

/// The default evaluator path (`SchemeEvaluator::tally`, what sweeps
/// actually call) routes through the batched kernel and equals the oracle —
/// guards the engine wiring, not just the kernel.
#[test]
fn evaluator_tally_routes_through_batched_kernel_and_matches() {
    use wdm_arbiter::montecarlo::SchemeEvaluator;
    let pop = population(&SystemConfig::default(), 8, 8, 99);
    for scheme in Scheme::all() {
        let ev = RustOblivious { scheme, threads: 2 };
        for tr in [3.0, 6.0, 9.0] {
            assert_eq!(ev.tally(&pop, tr), ev.tally_scalar(&pop, tr), "{} tr={tr}", scheme.name());
        }
    }
}
