//! End-to-end CLI tests: drive the real `wdm-arbiter` binary.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_wdm-arbiter"))
}

#[test]
fn list_shows_every_paper_artifact() {
    let out = bin().arg("list").output().expect("run");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for id in ["table1", "table2", "fig4", "fig5", "fig6", "fig7", "fig8", "fig14", "fig15", "fig16"] {
        assert!(text.contains(id), "missing {id} in list output");
    }
}

#[test]
fn arbitrate_prints_ideal_and_oblivious() {
    let out = bin()
        .args(["arbitrate", "--tr", "6", "--seed", "7"])
        .output()
        .expect("run");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("ideal LtC"));
    assert!(text.contains("oblivious vt-rs-ssm"));
}

#[test]
fn run_table1_writes_json() {
    let dir = std::env::temp_dir().join(format!("wdm-e2e-{}", std::process::id()));
    let out = bin()
        .args(["run", "table1", "--out"])
        .arg(&dir)
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(dir.join("table1.json").is_file());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_fig8_fast_tiny_population() {
    let dir = std::env::temp_dir().join(format!("wdm-e2e-fig8-{}", std::process::id()));
    let out = bin()
        .args(["run", "fig8", "--fast", "--lasers", "4", "--rows", "4", "--out"])
        .arg(&dir)
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fig8"));
    assert!(dir.join("fig8.json").is_file());
    assert!(dir.join("fig8_fsr_design.csv").is_file());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_subcommand_writes_grid_and_json() {
    let dir = std::env::temp_dir().join(format!("wdm-e2e-sweep-{}", std::process::id()));
    let out = bin()
        .args([
            "sweep", "--axis", "ring-local", "--values", "1.12,2.24", "--tr", "2,6",
            "--measure", "afp:ltc,cafp:vt-rs-ssm", "--fast", "--lasers", "4", "--rows", "4",
            "--out",
        ])
        .arg(&dir)
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("afp_ltc"), "{text}");
    assert!(dir.join("sweep_afp_ltc.csv").is_file());
    assert!(dir.join("sweep_cafp_vt-rs-ssm.csv").is_file());
    assert!(dir.join("sweep.json").is_file());
    let json = std::fs::read_to_string(dir.join("sweep.json")).unwrap();
    assert!(json.contains("\"axis\": \"ring-local\""));
    assert!(json.contains("\"backend\": \"rust-f64\""));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_range_syntax_and_curve_measure() {
    let dir = std::env::temp_dir().join(format!("wdm-e2e-sweep2-{}", std::process::id()));
    let out = bin()
        .args([
            "sweep", "--axis", "grid-offset", "--values", "0:2:1", "--measure", "min-tr:lta",
            "--fast", "--lasers", "3", "--rows", "3", "--out",
        ])
        .arg(&dir)
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(dir.join("sweep_min-tr_lta.csv").is_file());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_rejects_bad_axis() {
    let out = bin()
        .args(["sweep", "--axis", "warp-factor", "--values", "1,2"])
        .output()
        .expect("run");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown axis"));
}

#[test]
fn config_file_round_trip() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("wdm-cfg-{}.toml", std::process::id()));
    std::fs::write(
        &path,
        "[grid]\nn_ch = 16\nspacing_nm = 2.24\n[orders]\npre_fab = \"permuted\"\ntarget = \"permuted\"\n",
    )
    .unwrap();
    let out = bin().args(["show-config", "--config"]).arg(&path).output().expect("run");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("wdm16-400g"), "{text}");
    assert!(text.contains("(0,8,1,9,"), "{text}");
    std::fs::remove_file(path).ok();
}

#[test]
fn unknown_experiment_fails_cleanly() {
    let out = bin().args(["run", "fig99"]).output().expect("run");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown experiment"));
}

#[test]
fn seeded_runs_are_bit_identical() {
    let run = || {
        let out = bin()
            .args(["arbitrate", "--seed", "123", "--tr", "5.5"])
            .output()
            .expect("run");
        String::from_utf8_lossy(&out.stdout).to_string()
    };
    assert_eq!(run(), run());
}
