//! End-to-end CLI tests: drive the real `wdm-arbiter` binary.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_wdm-arbiter"))
}

#[test]
fn list_shows_every_paper_artifact() {
    let out = bin().arg("list").output().expect("run");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for id in ["table1", "table2", "fig4", "fig5", "fig6", "fig7", "fig8", "fig14", "fig15", "fig16"] {
        assert!(text.contains(id), "missing {id} in list output");
    }
}

#[test]
fn arbitrate_prints_ideal_and_oblivious() {
    let out = bin()
        .args(["arbitrate", "--tr", "6", "--seed", "7"])
        .output()
        .expect("run");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("ideal LtC"));
    assert!(text.contains("oblivious vt-rs-ssm"));
}

#[test]
fn run_table1_writes_json() {
    let dir = std::env::temp_dir().join(format!("wdm-e2e-{}", std::process::id()));
    let out = bin()
        .args(["run", "table1", "--out"])
        .arg(&dir)
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(dir.join("table1.json").is_file());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_fig8_fast_tiny_population() {
    let dir = std::env::temp_dir().join(format!("wdm-e2e-fig8-{}", std::process::id()));
    let out = bin()
        .args(["run", "fig8", "--fast", "--lasers", "4", "--rows", "4", "--out"])
        .arg(&dir)
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fig8"));
    assert!(dir.join("fig8.json").is_file());
    assert!(dir.join("fig8_fsr_design.csv").is_file());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_subcommand_writes_grid_and_json() {
    let dir = std::env::temp_dir().join(format!("wdm-e2e-sweep-{}", std::process::id()));
    let out = bin()
        .args([
            "sweep", "--axis", "ring-local", "--values", "1.12,2.24", "--tr", "2,6",
            "--measure", "afp:ltc,cafp:vt-rs-ssm", "--fast", "--lasers", "4", "--rows", "4",
            "--out",
        ])
        .arg(&dir)
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("afp_ltc"), "{text}");
    assert!(dir.join("sweep_afp_ltc.csv").is_file());
    assert!(dir.join("sweep_cafp_vt-rs-ssm.csv").is_file());
    assert!(dir.join("sweep.json").is_file());
    let json = std::fs::read_to_string(dir.join("sweep.json")).unwrap();
    assert!(json.contains("\"axis\": \"ring-local\""));
    assert!(json.contains("\"backend\": \"rust-f64\""));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_range_syntax_and_curve_measure() {
    let dir = std::env::temp_dir().join(format!("wdm-e2e-sweep2-{}", std::process::id()));
    let out = bin()
        .args([
            "sweep", "--axis", "grid-offset", "--values", "0:2:1", "--measure", "min-tr:lta",
            "--fast", "--lasers", "3", "--rows", "3", "--out",
        ])
        .arg(&dir)
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(dir.join("sweep_min-tr_lta.csv").is_file());
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance: a `--ci` sweep records per-cell `n_trials` (≤ the
/// population) and the Wilson interval in the JSON panel output.
#[test]
fn sweep_ci_records_adaptive_stats_in_json() {
    let dir = std::env::temp_dir().join(format!("wdm-e2e-ci-{}", std::process::id()));
    let out = bin()
        .args([
            "sweep", "--axis", "ring-local", "--values", "1.12,2.24", "--tr", "2,6",
            "--measure", "cafp:vt-rs-ssm", "--fast", "--lasers", "8", "--rows", "8",
            "--ci", "0.5", "--min-trials", "16", "--out",
        ])
        .arg(&dir)
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let json = std::fs::read_to_string(dir.join("sweep.json")).unwrap();
    assert!(json.contains("\"ci\""), "{json}");
    assert!(json.contains("\"n_trials\""), "{json}");
    assert!(json.contains("\"ci_lo\""), "{json}");
    assert!(json.contains("\"ci_hi\""), "{json}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Both scheduler paths (full and adaptive) honor --threads without
/// changing results: byte-identical sweep.json at 1 vs 8 workers.
#[test]
fn sweep_json_byte_identical_across_thread_counts() {
    let run_with = |threads: &str, tag: &str| {
        let dir = std::env::temp_dir().join(format!(
            "wdm-e2e-thr{tag}-{}",
            std::process::id()
        ));
        let out = bin()
            .args([
                "sweep", "--axis", "ring-local", "--values", "1.12,2.24,3.36", "--tr", "2,6",
                "--measure", "afp:ltc,cafp:vt-rs-ssm", "--fast", "--lasers", "4", "--rows",
                "4", "--threads", threads, "--out",
            ])
            .arg(&dir)
            .output()
            .expect("run");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        let json = std::fs::read_to_string(dir.join("sweep.json")).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        json
    };
    assert_eq!(run_with("1", "a"), run_with("8", "b"));
}

/// Acceptance: a scenario-axis sweep (fault probability 0 → 0.1) runs
/// scheduler-parallel end-to-end via the CLI, with byte-identical
/// sweep.json at 1 vs 4 workers.
#[test]
fn scenario_axis_sweep_runs_scheduler_parallel_via_cli() {
    let run_with = |threads: &str, tag: &str| {
        let dir = std::env::temp_dir().join(format!(
            "wdm-e2e-scen{tag}-{}",
            std::process::id()
        ));
        let out = bin()
            .args([
                "sweep", "--axis", "dead-tone-p", "--values", "0:0.1:0.05", "--tr",
                "4.48,6.72", "--measure", "afp:ltc,cafp:vt-rs-ssm", "--fast", "--lasers",
                "4", "--rows", "4", "--threads", threads, "--out",
            ])
            .arg(&dir)
            .output()
            .expect("run");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        let json = std::fs::read_to_string(dir.join("sweep.json")).unwrap();
        assert!(json.contains("\"axis\": \"dead-tone-p\""), "{json}");
        assert!(dir.join("sweep_cafp_vt-rs-ssm.csv").is_file());
        std::fs::remove_dir_all(&dir).ok();
        json
    };
    assert_eq!(run_with("1", "a"), run_with("4", "b"));
}

/// Scenario knobs flow from a --config file into show-config (and bad
/// knobs fail with a structured error, not a panic).
#[test]
fn scenario_config_file_renders_and_validates() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("wdm-scen-cfg-{}.toml", std::process::id()));
    std::fs::write(&path, "[scenario]\ndistribution = \"bimodal\"\ncorr_len = 2.0\n").unwrap();
    let out = bin().args(["show-config", "--config"]).arg(&path).output().expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("bimodal"), "{text}");
    assert!(text.contains("corr-len 2"), "{text}");

    std::fs::write(&path, "[scenario]\ndark_ring_p = 7.0\n").unwrap();
    let out = bin().args(["show-config", "--config"]).arg(&path).output().expect("run");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("dark_ring_p"));
    std::fs::remove_file(path).ok();
}

#[test]
fn sweep_rejects_bad_axis() {
    let out = bin()
        .args(["sweep", "--axis", "warp-factor", "--values", "1,2"])
        .output()
        .expect("run");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown axis"));
}

#[test]
fn config_file_round_trip() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("wdm-cfg-{}.toml", std::process::id()));
    std::fs::write(
        &path,
        "[grid]\nn_ch = 16\nspacing_nm = 2.24\n[orders]\npre_fab = \"permuted\"\ntarget = \"permuted\"\n",
    )
    .unwrap();
    let out = bin().args(["show-config", "--config"]).arg(&path).output().expect("run");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("wdm16-400g"), "{text}");
    assert!(text.contains("(0,8,1,9,"), "{text}");
    std::fs::remove_file(path).ok();
}

#[test]
fn unknown_experiment_fails_cleanly() {
    let out = bin().args(["run", "fig99"]).output().expect("run");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown experiment"));
}

/// Read envelope lines until the response for `id` arrives; returns it
/// (panicking on EOF). Event lines for any id are collected into `events`.
fn read_response_for(
    reader: &mut impl std::io::BufRead,
    id: &str,
    events: &mut Vec<String>,
) -> String {
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).expect("read serve output");
        assert!(n > 0, "serve closed before responding to id {id}");
        let l = line.trim();
        if l.is_empty() {
            continue;
        }
        if l.contains("\"event\"") {
            events.push(l.to_string());
            continue;
        }
        if l.starts_with(&format!("{{\"id\":{id},")) || l.starts_with(&format!("{{\"id\":\"{id}\",")) {
            return l.to_string();
        }
    }
}

/// Acceptance: a pipelined envelope session where the second, overlapping
/// sweep is served from the population cache (no resampling) and says so.
/// Request/response turns are sequenced by the client so the cache-delta
/// assertions stay deterministic.
#[test]
fn serve_session_reports_cache_hits_on_overlapping_sweeps() {
    use std::io::Write as _;
    use std::process::Stdio;
    let dir = std::env::temp_dir().join(format!("wdm-e2e-serve-{}", std::process::id()));
    let mut child = bin()
        .arg("serve")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn serve");
    let mut stdin = child.stdin.take().unwrap();
    let mut reader = std::io::BufReader::new(child.stdout.take().unwrap());
    let out = dir.display();
    let mut events = Vec::new();
    // Same axis/values/population shape/seed; different measures. The
    // second job must reuse both column populations.
    writeln!(
        stdin,
        r#"{{"id":1,"request":{{"type":"sweep","axis":"ring-local","values":[1.12,2.24],"tr":[2,6],"measures":["afp:ltc"],"options":{{"fast":true,"lasers":3,"rows":3,"out":"{out}"}}}}}}"#
    )
    .unwrap();
    let first = read_response_for(&mut reader, "1", &mut events);
    writeln!(
        stdin,
        r#"{{"id":2,"request":{{"type":"sweep","axis":"ring-local","values":[1.12,2.24],"tr":[2,6],"measures":["cafp:vt-rs-ssm"],"options":{{"fast":true,"lasers":3,"rows":3,"out":"{out}"}}}}}}"#
    )
    .unwrap();
    let second = read_response_for(&mut reader, "2", &mut events);
    drop(stdin); // EOF ends the session
    let output = child.wait_with_output().expect("serve exits");
    assert!(output.status.success(), "{}", String::from_utf8_lossy(&output.stderr));
    assert!(first.contains("\"ok\":true"), "{first}");
    assert!(first.contains("\"hits\":0"), "{first}");
    assert!(first.contains("\"misses\":2"), "{first}");
    assert!(second.contains("\"ok\":true"), "{second}");
    assert!(second.contains("\"hits\":2"), "{second}");
    assert!(second.contains("\"misses\":0"), "{second}");
    // Progress events arrived as id-tagged envelope lines.
    assert!(!events.is_empty());
    assert!(events.iter().all(|e| e.starts_with("{\"id\":")), "{events:?}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Malformed lines answer with the line number + a truncated payload echo
/// and never kill the connection; old bare (un-enveloped) requests are
/// named as such.
#[test]
fn serve_rejects_bad_request_lines_without_dying() {
    use std::io::Write as _;
    use std::process::Stdio;
    let mut child = bin()
        .arg("serve")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn serve");
    let mut stdin = child.stdin.take().unwrap();
    writeln!(stdin, "this is not json").unwrap();
    writeln!(stdin, r#"{{"type":"show-config"}}"#).unwrap(); // bare, un-enveloped
    writeln!(stdin, r#"{{"id":7,"request":{{"type":"show-config"}}}}"#).unwrap();
    drop(stdin);
    let output = child.wait_with_output().expect("serve exits");
    assert!(output.status.success());
    let text = String::from_utf8_lossy(&output.stdout);
    let responses: Vec<&str> = text.lines().filter(|l| l.contains("\"response\"")).collect();
    assert_eq!(responses.len(), 3, "{text}");
    let parse_errors: Vec<&str> =
        responses.iter().copied().filter(|l| l.starts_with("{\"id\":null,")).collect();
    assert_eq!(parse_errors.len(), 2, "{text}");
    assert!(parse_errors[0].contains("line 1"), "{}", parse_errors[0]);
    assert!(parse_errors[0].contains("payload: this is not json"), "{}", parse_errors[0]);
    assert!(parse_errors[1].contains("line 2"), "{}", parse_errors[1]);
    assert!(parse_errors[1].contains("unknown envelope key"), "{}", parse_errors[1]);
    let ok: Vec<&str> = responses
        .iter()
        .copied()
        .filter(|l| l.starts_with("{\"id\":7,") && l.contains("\"ok\":true"))
        .collect();
    assert_eq!(ok.len(), 1, "the valid envelope still ran:\n{text}");
}

/// Acceptance: two clients on one `serve --listen` instance run
/// overlapping sweeps; each connection's envelopes are id-tagged and
/// complete, cancel works across the wire, and a `shutdown` control
/// drains the server to a clean exit.
#[test]
fn serve_listen_serves_two_tcp_clients_and_shuts_down() {
    use std::io::{BufRead as _, BufReader, Write as _};
    use std::net::TcpStream;
    use std::process::Stdio;
    use std::time::Duration;

    let dir = std::env::temp_dir().join(format!("wdm-e2e-tcp-{}", std::process::id()));
    let mut child = bin()
        .args(["serve", "--listen", "127.0.0.1:0", "--threads", "2"])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn serve --listen");
    let mut server_out = BufReader::new(child.stdout.take().unwrap());
    let mut banner = String::new();
    server_out.read_line(&mut banner).expect("read listen banner");
    let addr = banner.trim().strip_prefix("listening on ").expect("banner").to_string();

    let connect = || {
        let s = TcpStream::connect(&addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        s
    };
    let submit = |w: &mut TcpStream, id: &str, measure: &str, sub: &str| {
        writeln!(
            w,
            r#"{{"id":"{id}","request":{{"type":"sweep","axis":"ring-local","values":[1.12,2.24],"tr":[2,6],"measures":["{measure}"],"options":{{"fast":true,"lasers":4,"rows":4,"out":"{}/{sub}"}}}}}}"#,
            dir.display()
        )
        .unwrap();
    };

    // Client X pipelines two jobs; client Y runs one concurrently.
    let mut x = connect();
    let mut y = connect();
    submit(&mut x, "x1", "afp:ltc", "x1");
    submit(&mut x, "x2", "cafp:vt-rs-ssm", "x2");
    submit(&mut y, "y1", "afp:ltc", "y1");

    let drain = |stream: &TcpStream, want: &[&str]| {
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut responses: Vec<String> = Vec::new();
        let mut line = String::new();
        while responses.len() < want.len() {
            line.clear();
            let n = reader.read_line(&mut line).expect("read envelope");
            assert!(n > 0, "connection closed early");
            let l = line.trim();
            // Every line this client sees belongs to one of ITS ids.
            assert!(
                want.iter().any(|id| l.starts_with(&format!("{{\"id\":\"{id}\","))),
                "foreign or untagged envelope: {l}"
            );
            if l.contains("\"response\"") {
                assert!(l.contains("\"ok\":true"), "{l}");
                responses.push(l.to_string());
            }
        }
        responses
    };
    let x_responses = drain(&x, &["x1", "x2"]);
    let y_responses = drain(&y, &["y1"]);
    assert_eq!(x_responses.len(), 2);
    assert_eq!(y_responses.len(), 1);

    // Client Y shuts the server down WHILE client X is still connected
    // and idle: the broadcast must unblock X's reader (X never hangs up).
    writeln!(y, r#"{{"id":"sd","control":"shutdown"}}"#).unwrap();
    let mut reader = BufReader::new(y.try_clone().unwrap());
    let mut ack = String::new();
    reader.read_line(&mut ack).expect("shutdown ack");
    assert!(ack.starts_with("{\"id\":\"sd\","), "{ack}");
    drop(y);
    let mut x_reader = BufReader::new(x.try_clone().unwrap());
    let mut tail = String::new();
    loop {
        tail.clear();
        match x_reader.read_line(&mut tail) {
            Ok(0) => break, // drained and closed by the shutdown broadcast
            Ok(_) => continue,
            Err(e) => panic!("client X was not unblocked by shutdown: {e}"),
        }
    }
    drop(x);
    let status = child.wait().expect("server exits");
    assert!(status.success(), "clean shutdown");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batch_runs_job_file_and_keeps_going_past_failures() {
    let dir = std::env::temp_dir().join(format!("wdm-e2e-batch-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let jobs_path = dir.join("jobs.json");
    std::fs::write(
        &jobs_path,
        format!(
            r#"[
  {{"type":"run","id":"table1","options":{{"out":"{0}"}}}},
  {{"type":"run","id":"fig99"}},
  {{"type":"show-config"}}
]"#,
            dir.display()
        ),
    )
    .unwrap();
    let out = bin().arg("batch").arg(&jobs_path).output().expect("run");
    assert!(!out.status.success(), "a failing job fails the batch exit code");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Table I"), "{text}");
    assert!(text.contains("FAIL run fig99"), "{text}");
    assert!(text.contains("ok   show-config"), "{text}");
    assert!(text.contains("cache:"), "{text}");
    assert!(dir.join("table1.json").is_file(), "first job ran to completion");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batch_accepts_toml_job_files() {
    let dir = std::env::temp_dir().join(format!("wdm-e2e-batch-toml-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let jobs_path = dir.join("jobs.toml");
    std::fs::write(
        &jobs_path,
        "[jobs.1]\ntype = \"show-config\"\n\n[jobs.2]\ntype = \"arbitrate\"\ntr = 6.0\nseed = 7\n",
    )
    .unwrap();
    let out = bin().arg("batch").arg(&jobs_path).output().expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("ok   show-config"), "{text}");
    assert!(text.contains("ok   arbitrate"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_all_writes_manifest_and_reports_backend() {
    let dir = std::env::temp_dir().join(format!("wdm-e2e-manifest-{}", std::process::id()));
    let out = bin()
        .args(["run", "all", "--fast", "--lasers", "3", "--rows", "3", "--out"])
        .arg(&dir)
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let manifest = std::fs::read_to_string(dir.join("manifest.json")).expect("manifest written");
    assert!(manifest.contains("\"id\": \"table1\""), "{manifest}");
    assert!(manifest.contains("\"id\": \"fig14\""), "{manifest}");
    assert!(manifest.contains("\"failures\": 0"), "{manifest}");
    assert!(manifest.contains("\"backend\""), "{manifest}");
    // Entries are sorted by experiment id, so the manifest stays stable
    // whatever order the concurrent scheduler finishes experiments in.
    let pos = |id: &str| manifest.find(&format!("\"id\": \"{id}\"")).expect(id);
    assert!(pos("fig14") < pos("fig4"), "lexicographic id order");
    assert!(pos("fig4") < pos("table1"), "lexicographic id order");
    assert!(pos("table1") < pos("table2"), "lexicographic id order");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("wrote"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn show_config_cases_respects_config_file() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("wdm-cases-cfg-{}.toml", std::process::id()));
    std::fs::write(&path, "[grid]\nn_ch = 16\nspacing_nm = 2.24\n").unwrap();
    let out = bin()
        .args(["show-config", "--cases", "--config"])
        .arg(&path)
        .output()
        .expect("run");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    // The permuted 16-channel ordering (0,8,…) proves the case table was
    // rendered against the loaded config, not the default one.
    assert!(text.contains("LtC-P/P"), "{text}");
    assert!(text.contains("(0,8,"), "{text}");
    std::fs::remove_file(path).ok();
}

#[test]
fn seeded_runs_are_bit_identical() {
    let run = || {
        let out = bin()
            .args(["arbitrate", "--seed", "123", "--tr", "5.5"])
            .output()
            .expect("run");
        String::from_utf8_lossy(&out.stdout).to_string()
    };
    assert_eq!(run(), run());
}
