//! End-to-end CLI tests: drive the real `wdm-arbiter` binary.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_wdm-arbiter"))
}

#[test]
fn list_shows_every_paper_artifact() {
    let out = bin().arg("list").output().expect("run");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for id in ["table1", "table2", "fig4", "fig5", "fig6", "fig7", "fig8", "fig14", "fig15", "fig16"] {
        assert!(text.contains(id), "missing {id} in list output");
    }
}

#[test]
fn arbitrate_prints_ideal_and_oblivious() {
    let out = bin()
        .args(["arbitrate", "--tr", "6", "--seed", "7"])
        .output()
        .expect("run");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("ideal LtC"));
    assert!(text.contains("oblivious vt-rs-ssm"));
}

#[test]
fn run_table1_writes_json() {
    let dir = std::env::temp_dir().join(format!("wdm-e2e-{}", std::process::id()));
    let out = bin()
        .args(["run", "table1", "--out"])
        .arg(&dir)
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(dir.join("table1.json").is_file());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_fig8_fast_tiny_population() {
    let dir = std::env::temp_dir().join(format!("wdm-e2e-fig8-{}", std::process::id()));
    let out = bin()
        .args(["run", "fig8", "--fast", "--lasers", "4", "--rows", "4", "--out"])
        .arg(&dir)
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fig8"));
    assert!(dir.join("fig8.json").is_file());
    assert!(dir.join("fig8_fsr_design.csv").is_file());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn config_file_round_trip() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("wdm-cfg-{}.toml", std::process::id()));
    std::fs::write(
        &path,
        "[grid]\nn_ch = 16\nspacing_nm = 2.24\n[orders]\npre_fab = \"permuted\"\ntarget = \"permuted\"\n",
    )
    .unwrap();
    let out = bin().args(["show-config", "--config"]).arg(&path).output().expect("run");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("wdm16-400g"), "{text}");
    assert!(text.contains("(0,8,1,9,"), "{text}");
    std::fs::remove_file(path).ok();
}

#[test]
fn unknown_experiment_fails_cleanly() {
    let out = bin().args(["run", "fig99"]).output().expect("run");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown experiment"));
}

#[test]
fn seeded_runs_are_bit_identical() {
    let run = || {
        let out = bin()
            .args(["arbitrate", "--seed", "123", "--tr", "5.5"])
            .output()
            .expect("run");
        String::from_utf8_lossy(&out.stdout).to_string()
    };
    assert_eq!(run(), run());
}
