//! Concurrency suite for the session API (`submit_async` / `JobHandle`):
//!
//! * determinism — N jobs submitted concurrently produce byte-identical
//!   panels to the same jobs run sequentially (per-column seeding makes
//!   interleaving invisible);
//! * cancellation — a cancel mid-sweep returns a `canceled` response
//!   within one column's granularity, leaves the shared
//!   `PopulationCache` consistent, and subsequent jobs still succeed.

use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use wdm_arbiter::api::{ArbiterService, FnSink, JobEvent, JobRequest, JobStatus, Panel};
use wdm_arbiter::coordinator::Backend;

fn test_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("wdm-session-{tag}-{}", std::process::id()))
}

fn sweep(json: &str) -> JobRequest {
    JobRequest::from_json_str(json).unwrap_or_else(|e| panic!("{e} in {json}"))
}

/// Four distinct sweep jobs (grid + curve measures, two axes).
fn job_mix(dir: &std::path::Path) -> Vec<JobRequest> {
    let d = dir.display();
    vec![
        sweep(&format!(
            r#"{{"type":"sweep","axis":"ring-local","values":[1.12,2.24],"tr":[2,6],
                "measures":"afp:ltc","options":{{"fast":true,"lasers":4,"rows":4,
                "threads":2,"out":"{d}/j0"}}}}"#
        )),
        sweep(&format!(
            r#"{{"type":"sweep","axis":"grid-offset","values":[0,1],"tr":[2,6],
                "measures":"afp:lta,afp:ltd","options":{{"fast":true,"lasers":4,"rows":4,
                "threads":2,"out":"{d}/j1"}}}}"#
        )),
        sweep(&format!(
            r#"{{"type":"sweep","axis":"ring-local","values":[1.12,2.24],"tr":[2,6],
                "measures":"cafp:vt-rs-ssm","options":{{"fast":true,"lasers":4,"rows":4,
                "threads":2,"out":"{d}/j2"}}}}"#
        )),
        sweep(&format!(
            r#"{{"type":"sweep","axis":"fsr-frac","values":[0.005,0.01],
                "measures":"min-tr:ltc","options":{{"fast":true,"lasers":4,"rows":4,
                "threads":2,"out":"{d}/j3"}}}}"#
        )),
    ]
}

/// (a) Concurrent submissions are invisible in the results: panels from N
/// jobs in flight together are byte-identical to sequential runs.
#[test]
fn concurrent_submissions_match_sequential_panels() {
    let dir = test_dir("determinism");
    let jobs = job_mix(&dir);

    // Reference: one fresh service, strictly sequential.
    let sequential = ArbiterService::new(Backend::Rust, 2);
    let expected: Vec<Vec<Panel>> = jobs
        .iter()
        .map(|j| {
            let resp = sequential.submit(j);
            assert!(resp.ok, "{:?}", resp.error);
            resp.panels
        })
        .collect();

    // Same jobs, all in flight at once on a fresh service.
    let concurrent = ArbiterService::new(Backend::Rust, 2).with_job_workers(4);
    let handles: Vec<_> = jobs.iter().map(|j| concurrent.submit_async(j.clone())).collect();
    for (i, (h, want)) in handles.iter().zip(&expected).enumerate() {
        let resp = h.wait();
        assert!(resp.ok, "job {i}: {:?}", resp.error);
        assert_eq!(&resp.panels, want, "job {i}: concurrent != sequential");
    }

    // Identical jobs submitted concurrently coalesce on the population
    // cache — and still return byte-identical panels.
    let coalesced = ArbiterService::new(Backend::Rust, 2).with_job_workers(4);
    let copies: Vec<_> = (0..4).map(|_| coalesced.submit_async(jobs[0].clone())).collect();
    for h in &copies {
        let resp = h.wait();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.panels, expected[0]);
        assert_eq!(resp.panels[0].measure(), "afp_ltc");
    }
    let stats = coalesced.cache().stats();
    assert_eq!(
        stats.hits + stats.misses,
        8,
        "4 copies x 2 columns, each either built once or coalesced/hit"
    );
    assert_eq!(stats.misses, 2, "each column sampled exactly once across all copies");

    std::fs::remove_dir_all(dir).ok();
}

/// (b) Cancel mid-sweep: `canceled` response within one column's
/// granularity, consistent cache, healthy service afterwards.
#[test]
fn cancel_mid_sweep_reports_canceled_and_cache_stays_consistent() {
    let dir = test_dir("cancel");
    let d = dir.display();
    // 16 serial columns (threads 1) of 400 trials: the cancel — issued on
    // the FIRST ColumnDone event — lands with ~15 columns of margin.
    let big = sweep(&format!(
        r#"{{"type":"sweep","axis":"ring-local","values":"0.56:8.96:0.56","tr":[2,6,9],
            "measures":"cafp:vt-rs-ssm","options":{{"fast":true,"lasers":20,"rows":20,
            "threads":1,"out":"{d}/big"}}}}"#
    ));
    let service = ArbiterService::new(Backend::Rust, 1).with_job_workers(2);

    let (first_col_tx, first_col_rx) = mpsc::channel::<()>();
    let tx = Mutex::new(Some(first_col_tx));
    let sink = Arc::new(FnSink(move |ev: JobEvent| {
        if matches!(ev, JobEvent::ColumnDone { .. }) {
            if let Some(tx) = tx.lock().unwrap().take() {
                let _ = tx.send(());
            }
        }
    }));
    let handle = service.submit_async_with(big.clone(), sink);
    first_col_rx
        .recv_timeout(Duration::from_secs(120))
        .expect("first column finished");
    handle.cancel();
    let resp = handle.wait();
    assert!(resp.canceled, "expected canceled, got {resp:?}");
    assert!(!resp.ok);
    assert_eq!(resp.error.as_deref(), Some("canceled"));
    assert_eq!(handle.status(), JobStatus::Canceled);
    assert!(resp.panels.is_empty(), "a canceled grid carries no partial panels");
    let after_cancel = service.cache().stats();
    assert!(after_cancel.misses >= 1, "completed columns were cached");
    assert!(after_cancel.misses < 16, "the sweep did not run to completion");

    // Cache consistency: the interrupted columns are whole — re-running
    // the same sweep reuses them and matches a fresh, never-canceled run.
    let rerun = service.submit(&big);
    assert!(rerun.ok, "{:?}", rerun.error);
    assert_eq!(rerun.cache.hits, after_cancel.misses, "canceled columns reused");
    assert_eq!(rerun.cache.hits + rerun.cache.misses, 16);
    let fresh_dir = test_dir("cancel-fresh");
    let fresh_job = sweep(&format!(
        r#"{{"type":"sweep","axis":"ring-local","values":"0.56:8.96:0.56","tr":[2,6,9],
            "measures":"cafp:vt-rs-ssm","options":{{"fast":true,"lasers":20,"rows":20,
            "threads":1,"out":"{}/big"}}}}"#,
        fresh_dir.display()
    ));
    let fresh = ArbiterService::new(Backend::Rust, 1).submit(&fresh_job);
    assert!(fresh.ok, "{:?}", fresh.error);
    assert_eq!(rerun.panels, fresh.panels, "post-cancel results are unpolluted");

    // And unrelated follow-up jobs still succeed on the same service.
    let follow = service.submit(&JobRequest::from_json_str(r#"{"type":"show-config"}"#).unwrap());
    assert!(follow.ok);

    std::fs::remove_dir_all(dir).ok();
    std::fs::remove_dir_all(fresh_dir).ok();
}

/// Canceling an already-finished job is a no-op: the result stands.
#[test]
fn cancel_after_completion_keeps_the_result() {
    let dir = test_dir("late-cancel");
    let job = sweep(&format!(
        r#"{{"type":"sweep","axis":"ring-local","values":[1.12],"tr":[6],
            "measures":"afp:ltc","options":{{"fast":true,"lasers":3,"rows":3,
            "out":"{}"}}}}"#,
        dir.display()
    ));
    let service = ArbiterService::new(Backend::Rust, 1);
    let handle = service.submit_async(job);
    let resp = handle.wait();
    assert!(resp.ok);
    handle.cancel();
    assert_eq!(handle.status(), JobStatus::Done, "late cancel cannot rewrite history");
    assert!(handle.try_response().unwrap().ok);
    std::fs::remove_dir_all(dir).ok();
}
