//! Scenario-layer integration tests:
//!
//! * the exhaustive `SystemConfig` fingerprint test — mutate **every**
//!   field (including each scenario field) one at a time and assert the
//!   population-cache key changes, so a stale-population bug cannot hide;
//! * the default-scenario bit-identity contract — uniform / no-correlation
//!   / no-fault sampling reproduces the paper's RNG stream draw for draw;
//! * scenario sweeps running scheduler-parallel end-to-end with
//!   thread-count-independent panels, under fault injection included.

use wdm_arbiter::api::{ArbiterService, JobRequest, Panel};
use wdm_arbiter::arbiter::Policy;
use wdm_arbiter::config::SystemConfig;
use wdm_arbiter::coordinator::sweep::{ConfigAxis, Measure, SweepSpec};
use wdm_arbiter::coordinator::{Backend, RunOptions};
use wdm_arbiter::model::system::SystemSampler;
use wdm_arbiter::model::{
    CorrelationConfig, Distribution, DwdmGrid, FaultsConfig, MwlSample, RingRowSample,
    SpectralOrdering, VariationConfig,
};
use wdm_arbiter::montecarlo::scheduler::run_sweep;
use wdm_arbiter::montecarlo::{
    config_fingerprint, CancelToken, PopulationCache, RustIdeal, TrialEngine,
};
use wdm_arbiter::rng::{derive_seed, Rng};

/// Every user-settable `SystemConfig` field, one mutation each. Adding a
/// field to any nested config struct without extending this list is fine —
/// the fingerprint derives from `Debug` and covers it automatically — but
/// the list pins that no existing field ever silently drops out.
fn field_mutations() -> Vec<(&'static str, SystemConfig)> {
    let base = SystemConfig::default;
    let mut out: Vec<(&'static str, SystemConfig)> = Vec::new();
    let mut push = |name: &'static str, f: &dyn Fn(&mut SystemConfig)| {
        let mut cfg = base();
        f(&mut cfg);
        out.push((name, cfg));
    };
    push("grid.n_ch", &|c| c.grid.n_ch = 16);
    push("grid.spacing_nm", &|c| c.grid.spacing_nm = 2.24);
    push("variation.grid_offset_nm", &|c| c.variation.grid_offset_nm = 7.0);
    push("variation.laser_local_frac", &|c| c.variation.laser_local_frac = 0.4);
    push("variation.ring_local_nm", &|c| c.variation.ring_local_nm = 1.0);
    push("variation.fsr_frac", &|c| c.variation.fsr_frac = 0.02);
    push("variation.tr_frac", &|c| c.variation.tr_frac = 0.2);
    push("ring_bias_nm", &|c| c.ring_bias_nm = 3.0);
    push("fsr_mean_nm", &|c| c.fsr_mean_nm = 9.5);
    push("pre_fab_order", &|c| c.pre_fab_order = SpectralOrdering::permuted(8));
    push("target_order", &|c| c.target_order = SpectralOrdering::permuted(8));
    push("scenario.distribution (kind: trimmed-gaussian)", &|c| {
        c.scenario.distribution = Distribution::by_name("trimmed-gaussian").unwrap()
    });
    push("scenario.distribution.sigma_frac", &|c| {
        c.scenario.distribution = Distribution::TrimmedGaussian { sigma_frac: 0.4, clip: 3.0 }
    });
    push("scenario.distribution.clip", &|c| {
        c.scenario.distribution = Distribution::TrimmedGaussian {
            sigma_frac: wdm_arbiter::model::scenario::UNIFORM_EQUIV_SIGMA_FRAC,
            clip: 2.0,
        }
    });
    push("scenario.distribution (kind: bimodal)", &|c| {
        c.scenario.distribution = Distribution::by_name("bimodal").unwrap()
    });
    push("scenario.distribution.separation_frac", &|c| {
        c.scenario.distribution = Distribution::Bimodal { separation_frac: 0.9, jitter_frac: 0.3 }
    });
    push("scenario.distribution.jitter_frac", &|c| {
        c.scenario.distribution = Distribution::Bimodal { separation_frac: 0.7, jitter_frac: 0.1 }
    });
    push("scenario.correlation.gradient_nm", &|c| {
        c.scenario.correlation.gradient_nm = 1.5
    });
    push("scenario.correlation.corr_len", &|c| c.scenario.correlation.corr_len = 3.0);
    push("scenario.faults.dead_tone_p", &|c| c.scenario.faults.dead_tone_p = 0.01);
    push("scenario.faults.dark_ring_p", &|c| c.scenario.faults.dark_ring_p = 0.01);
    push("scenario.faults.weak_ring_p", &|c| c.scenario.faults.weak_ring_p = 0.01);
    push("scenario.faults.weak_tr_factor", &|c| c.scenario.faults.weak_tr_factor = 0.25);
    push("scenario.sampling.tilt", &|c| c.scenario.sampling.tilt = 4.0);
    push("scenario.sampling.stratified", &|c| c.scenario.sampling.stratified = true);
    out
}

/// Satellite: every field mutation must change the population-cache
/// fingerprint — a missed field silently serves stale populations.
#[test]
fn every_config_field_changes_the_cache_fingerprint() {
    let base_fp = config_fingerprint(&SystemConfig::default());
    let mutations = field_mutations();
    for (name, cfg) in &mutations {
        assert_ne!(
            config_fingerprint(cfg),
            base_fp,
            "mutating {name} must change the population-cache key"
        );
    }
    // And the mutations are pairwise distinct: no two fields alias onto
    // the same fingerprint (e.g. a sigma_frac change must not look like a
    // clip change).
    for i in 0..mutations.len() {
        for j in (i + 1)..mutations.len() {
            assert_ne!(
                config_fingerprint(&mutations[i].1),
                config_fingerprint(&mutations[j].1),
                "{} and {} alias in the fingerprint",
                mutations[i].0,
                mutations[j].0
            );
        }
    }
}

/// The fingerprint drives real cache behavior: a scenario-field change is
/// a miss, an identical scenario is a hit.
#[test]
fn cache_misses_on_scenario_change_and_hits_on_equality() {
    let ideal = RustIdeal::default();
    let cache = PopulationCache::new();
    let engine = TrialEngine::new(&ideal, 1).with_cache(&cache);
    let cfg = SystemConfig::default();
    engine.population(&cfg, 3, 3, 7, &[Policy::LtC]);
    engine.population(&cfg, 3, 3, 7, &[Policy::LtC]);
    assert_eq!(cache.stats().hits, 1);
    assert_eq!(cache.stats().misses, 1);

    let mut faulty = cfg.clone();
    faulty.scenario.faults.dead_tone_p = 0.5;
    engine.population(&faulty, 3, 3, 7, &[Policy::LtC]);
    assert_eq!(cache.stats().misses, 2, "scenario change must resample");
    assert_eq!(cache.stats().entries, 2);
}

/// Tentpole lock: the default scenario draws the exact RNG stream of the
/// paper's uniform model — the reference below is the pre-scenario
/// sampling code, inlined. Any extra or reordered draw in the default
/// path breaks this (and with it, every golden digest).
#[test]
fn default_scenario_is_bit_identical_to_paper_sampling() {
    let cfg = SystemConfig::default();
    let seed = 0xC0FFEE_u64;

    // Lasers: offset then per-tone local, all uniform half-range.
    for i in 0..5u64 {
        let stream = derive_seed(seed, &[0xA5, i]);
        let mut rng = Rng::seed_from(stream);
        let offset = rng.half_range(cfg.variation.grid_offset_nm);
        let local_half = cfg.variation.laser_local_frac * cfg.grid.spacing_nm;
        let want: Vec<f64> = (0..cfg.grid.n_ch)
            .map(|t| cfg.grid.slot_nm(t) + offset + rng.half_range(local_half))
            .collect();

        let mut rng = Rng::seed_from(stream);
        let got = MwlSample::sample(&cfg.grid, &cfg.variation, &cfg.scenario, &mut rng);
        assert_eq!(got.grid_offset_nm.to_bits(), offset.to_bits(), "laser {i} offset");
        for (a, b) in got.tones_nm.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits(), "laser {i} tone");
        }
        assert!(got.dead.is_empty(), "no fault draws in the default scenario");
    }

    // Ring rows: interleaved local / FSR / TR draws per ring.
    for j in 0..5u64 {
        let stream = derive_seed(seed, &[0x5A, j]);
        let mut rng = Rng::seed_from(stream);
        let mut want_res = Vec::new();
        let mut want_fsr = Vec::new();
        let mut want_tr = Vec::new();
        for r in 0..cfg.grid.n_ch {
            let slot = cfg.grid.slot_nm(cfg.pre_fab_order.slot_of(r));
            want_res.push(slot - cfg.ring_bias_nm + rng.half_range(cfg.variation.ring_local_nm));
            want_fsr.push(cfg.fsr_mean_nm * (1.0 + rng.half_range(cfg.variation.fsr_frac)));
            want_tr.push(1.0 + rng.half_range(cfg.variation.tr_frac));
        }

        let mut rng = Rng::seed_from(stream);
        let got = RingRowSample::sample(
            &cfg.grid,
            &cfg.pre_fab_order,
            cfg.ring_bias_nm,
            cfg.fsr_mean_nm,
            &cfg.variation,
            &cfg.scenario,
            &mut rng,
        );
        for r in 0..cfg.grid.n_ch {
            assert_eq!(got.resonance_nm[r].to_bits(), want_res[r].to_bits(), "row {j} ring {r}");
            assert_eq!(got.fsr_nm[r].to_bits(), want_fsr[r].to_bits(), "row {j} fsr {r}");
            assert_eq!(got.tr_scale[r].to_bits(), want_tr[r].to_bits(), "row {j} tr {r}");
        }
        assert!(got.dark.is_empty());
    }

    // And the population sampler wires exactly these streams.
    let sampler = SystemSampler::new(&cfg, 3, 3, seed);
    let mut rng = Rng::seed_from(derive_seed(seed, &[0xA5, 1]));
    let again = MwlSample::sample(&cfg.grid, &cfg.variation, &cfg.scenario, &mut rng);
    assert_eq!(sampler.lasers[1], again);
}

fn fault_spec(values: Vec<f64>) -> SweepSpec {
    SweepSpec::new("scenario-e2e", SystemConfig::default(), ConfigAxis::DeadToneP, values)
        .thresholds(vec![4.48, 6.72])
        .measures([
            Measure::Afp(Policy::LtC),
            Measure::Cafp(wdm_arbiter::oblivious::Scheme::VtRsSsm),
        ])
}

/// Scenario axes run through the column-parallel scheduler with panels
/// bit-identical at every thread count — faults, correlation and
/// non-uniform distributions included.
#[test]
fn scenario_sweeps_are_thread_count_invariant() {
    let spec_fault = fault_spec(vec![0.0, 0.1, 0.5]);
    let mut corr_base = SystemConfig::default();
    corr_base.scenario.distribution = Distribution::by_name("trimmed-gaussian").unwrap();
    corr_base.scenario.correlation = CorrelationConfig { gradient_nm: 2.0, corr_len: 3.0 };
    let spec_corr = SweepSpec::new("scenario-corr", corr_base, ConfigAxis::RingLocalNm, vec![
        1.12, 2.24,
    ])
    .thresholds(vec![4.48, 6.72])
    .measures([Measure::Afp(Policy::LtC)]);

    for spec in [&spec_fault, &spec_corr] {
        let run_at = |threads: usize| {
            let opts =
                RunOptions { n_lasers: 6, n_rows: 6, threads, ..RunOptions::fast() };
            run_sweep(spec, &opts, &Backend::Rust, None, &CancelToken::new(), &mut |_| {})
                .expect("sweep")
                .outputs
        };
        let one = run_at(1);
        let four = run_at(4);
        assert_eq!(one, four, "{}: panels must not depend on thread count", spec.tag);
    }
}

/// AFP under dead-tone injection is monotone in the fault probability and
/// saturates at 1 when every tone is dead; CAFP stays gated (no panic,
/// no conditional failures when the ideal model already failed).
#[test]
fn fault_probability_degrades_afp_monotonically() {
    let spec = fault_spec(vec![0.0, 1.0]);
    let opts = RunOptions { n_lasers: 5, n_rows: 5, threads: 2, ..RunOptions::fast() };
    let outs = run_sweep(&spec, &opts, &Backend::Rust, None, &CancelToken::new(), &mut |_| {})
        .expect("sweep")
        .outputs;
    let afp = outs[0].clone().into_shmoo();
    for iy in 0..2 {
        assert!(afp.at(0, iy) < 1.0, "fault-free default is not uniformly infeasible");
        assert_eq!(afp.at(1, iy), 1.0, "all tones dead: infeasible everywhere");
        assert!(afp.at(0, iy) <= afp.at(1, iy), "faults only degrade AFP");
    }
    let (cafp, tallies) = outs[1].clone().into_cafp();
    for iy in 0..2 {
        assert_eq!(cafp.at(1, iy), 0.0, "CAFP conditions on ideal success");
    }
    // Every faulted trial is a policy failure, none a conditional one.
    let nx = 2;
    for iy in 0..2 {
        let t = &tallies[iy * nx + 1];
        assert_eq!(t.policy_failures, t.trials);
        assert_eq!(t.conditional_failures, 0);
    }
}

/// The example scenario job file stays parseable and its axes resolve —
/// CI executes it end-to-end via `wdm-arbiter batch`.
#[test]
fn example_scenario_batch_file_parses() {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/jobs/scenario_sweep.toml"
    );
    let text = std::fs::read_to_string(path).expect("example job file");
    let JobRequest::Batch { jobs } = JobRequest::from_toml(&text).expect("parse") else {
        panic!("expected a batch")
    };
    assert_eq!(jobs.len(), 2);
    let JobRequest::Sweep { axis, .. } = &jobs[0] else { panic!("sweep") };
    assert_eq!(*axis, ConfigAxis::DeadToneP);
    // The referenced scenario config file parses and validates too.
    let cfg_path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/configs/scenario_correlated.toml"
    );
    let cfg = wdm_arbiter::config::presets::system_config_from_toml(
        &std::fs::read_to_string(cfg_path).expect("example config"),
    )
    .expect("valid scenario config");
    assert!(cfg.scenario.is_generalized());
    assert_eq!(cfg.scenario.distribution.name(), "trimmed-gaussian");
}

/// A scenario sweep through the whole service stack (the `batch`/`serve`
/// path), asserting cache reuse across jobs that share scenario columns.
#[test]
fn service_scenario_sweep_shares_population_cache() {
    let dir = std::env::temp_dir().join(format!("wdm-scenario-{}", std::process::id()));
    let service = ArbiterService::new(Backend::Rust, 2);
    let job = |measures: &str| {
        JobRequest::from_json_str(&format!(
            r#"{{"type":"sweep","axis":"corr-len","values":[0.5,3.0],"tr":[4.48],
                "measures":"{measures}",
                "options":{{"fast":true,"lasers":4,"rows":4,"out":"{}"}}}}"#,
            dir.display()
        ))
        .unwrap()
    };
    let first = service.submit(&job("afp:ltc"));
    assert!(first.ok, "{:?}", first.error);
    assert_eq!(first.cache.misses, 2, "one population per corr-len column");
    let second = service.submit(&job("cafp:vt-rs-ssm"));
    assert!(second.ok, "{:?}", second.error);
    assert_eq!(second.cache.hits, 2, "same scenario columns: served from cache");
    assert_eq!(second.cache.misses, 0);
    let Panel::Grid { cells, .. } = &second.panels[0] else { panic!("grid") };
    assert!(cells.iter().all(|c| c.is_finite()));
    std::fs::remove_dir_all(dir).ok();
}

/// Weak-ring faults shrink tuning ranges: the min-TR-for-complete-success
/// curve can only move up when every ring's tuner is halved.
#[test]
fn weak_rings_raise_min_tr() {
    let mut weak = SystemConfig::default();
    weak.scenario.faults = FaultsConfig {
        weak_ring_p: 1.0,
        weak_tr_factor: 0.5,
        ..FaultsConfig::default()
    };
    let healthy = SystemConfig::default();
    // Same seed, identical draws up to the (appended) weak-ring stream:
    // the weak population is the healthy one with every TR halved.
    let a = SystemSampler::new(&healthy, 4, 4, 11);
    let b = SystemSampler::new(&weak, 4, 4, 11);
    assert_eq!(a.lasers, b.lasers, "laser stream untouched by ring faults");
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.resonance_nm, rb.resonance_nm);
        for (sa, sb) in ra.tr_scale.iter().zip(&rb.tr_scale) {
            assert!((sb - 0.5 * sa).abs() < 1e-15);
        }
    }
}

/// Distribution families actually change the sampled populations (no
/// silent fallback to uniform), while grids/seeds stay shared.
#[test]
fn distribution_families_produce_distinct_populations() {
    let mk = |name: &str| {
        let mut cfg = SystemConfig::default();
        cfg.scenario.distribution = Distribution::by_name(name).unwrap();
        SystemSampler::new(&cfg, 3, 3, 99)
    };
    let uniform = mk("uniform");
    let gauss = mk("trimmed-gaussian");
    let bimodal = mk("bimodal");
    assert_ne!(uniform.lasers, gauss.lasers);
    assert_ne!(uniform.lasers, bimodal.lasers);
    assert_ne!(gauss.lasers, bimodal.lasers);
    // Bimodal local offsets avoid the origin: |Δ| >= (sep − jitter)·σ.
    let var = VariationConfig::default();
    let grid = DwdmGrid::wdm8_g200();
    for row in &bimodal.rows {
        for (i, &res) in row.resonance_nm.iter().enumerate() {
            let delta = res - (grid.slot_nm(i) - 4.48);
            assert!(delta.abs() >= (0.7 - 0.3) * var.ring_local_nm - 1e-9);
        }
    }
}
