//! Golden-digest regression tests: pin a stable FNV-1a digest of each
//! fig4/fig7/fig14-style panel at 64 trials (8×8) so any future refactor
//! that perturbs sampling, seed derivation, policy evaluation, or tally
//! order fails loudly.
//!
//! The pinned digests live in `tests/golden_digests.json`. On a machine
//! where an entry is missing the test computes and **blesses** it (writes
//! the file and passes) — commit the updated file to activate the pin.
//! `WDM_BLESS_GOLDEN=1 cargo test -q golden` re-blesses everything after
//! an *intentional* change to sampling or seeding.
//!
//! Independently of the pin file, this suite hard-asserts that the
//! sequential engine path and the column-parallel scheduler produce the
//! same digest at every thread count — the scheduler can never drift from
//! the reference implementation unnoticed.

use std::collections::BTreeMap;

use wdm_arbiter::arbiter::Policy;
use wdm_arbiter::config::SystemConfig;
use wdm_arbiter::coordinator::sweep::{ConfigAxis, Measure, SweepOutput, SweepSpec};
use wdm_arbiter::coordinator::{Backend, RunOptions};
use wdm_arbiter::model::system::SystemSampler;
use wdm_arbiter::montecarlo::scheduler::run_sweep;
use wdm_arbiter::montecarlo::{CancelToken, IdealEvaluator, RustIdeal, TrialEngine};
use wdm_arbiter::oblivious::Scheme;
use wdm_arbiter::util::json::Json;

const GOLDEN_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden_digests.json");

/// FNV-1a 64-bit over a byte stream.
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn push(&mut self, byte: u8) {
        self.0 ^= byte as u64;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    fn f64s(&mut self, xs: &[f64]) {
        for x in xs {
            for b in x.to_bits().to_le_bytes() {
                self.push(b);
            }
        }
    }

    fn u64(&mut self, x: u64) {
        for b in x.to_le_bytes() {
            self.push(b);
        }
    }

    fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

/// Stable digest of one sweep output: axes, cells, and (for CAFP) the full
/// tally breakdown, so a change to any recorded number trips the pin.
fn output_digest(out: &SweepOutput) -> String {
    let mut h = Fnv::new();
    match out {
        SweepOutput::Curve(series) => {
            h.u64(1);
            h.f64s(&series.x);
            h.f64s(&series.y);
        }
        SweepOutput::Grid(shmoo) => {
            h.u64(2);
            h.f64s(&shmoo.x);
            h.f64s(&shmoo.y);
            h.f64s(&shmoo.cells);
        }
        SweepOutput::CafpGrid { cafp, tallies } => {
            h.u64(3);
            h.f64s(&cafp.x);
            h.f64s(&cafp.y);
            h.f64s(&cafp.cells);
            for t in tallies {
                h.u64(t.trials as u64);
                h.u64(t.policy_failures as u64);
                h.u64(t.conditional_failures as u64);
                h.u64(t.lock_errors as u64);
                h.u64(t.lane_order_errors as u64);
            }
        }
        SweepOutput::EstGrid { grid, cells } => {
            h.u64(4);
            h.f64s(&grid.x);
            h.f64s(&grid.y);
            h.f64s(&grid.cells);
            for c in cells {
                h.u64(c.n_trials as u64);
                h.f64s(&[c.p, c.lo, c.hi]);
            }
        }
    }
    h.hex()
}

/// The pinned panels: fig4 (AFP shmoos, three policies), fig7 (min-TR
/// curve over grid offset), fig14 (CAFP shmoos, all schemes) — each at the
/// experiment's real tag + seed stream, 8×8 = 64 trials.
fn golden_specs() -> Vec<SweepSpec> {
    vec![
        SweepSpec::new(
            "fig4",
            SystemConfig::default(),
            ConfigAxis::RingLocalNm,
            vec![1.12, 2.24, 4.48],
        )
        .thresholds(vec![2.0, 4.0, 6.0, 9.0])
        .measures([
            Measure::Afp(Policy::LtA),
            Measure::Afp(Policy::LtC),
            Measure::Afp(Policy::LtD),
        ]),
        SweepSpec::new(
            "fig7",
            SystemConfig::default(),
            ConfigAxis::GridOffsetNm,
            vec![0.0, 5.0, 10.0, 15.0],
        )
        .measures([Measure::MinTrComplete(Policy::LtC), Measure::MinTrComplete(Policy::LtA)]),
        SweepSpec::new(
            "fig14",
            SystemConfig::default(),
            ConfigAxis::RingLocalNm,
            vec![1.12, 2.24],
        )
        .thresholds(vec![2.0, 6.0, 9.0])
        .measures(Scheme::all().into_iter().map(Measure::Cafp)),
    ]
}

/// The scalar trial-at-a-time oracle as an engine backend. `RustIdeal`
/// itself now routes through the batched SoA kernel
/// (`arbiter::batch`), so pinning *both* paths to the same digests is what
/// proves the hot-path restructuring moved zero bits.
struct ScalarIdeal;

impl IdealEvaluator for ScalarIdeal {
    fn min_trs(&self, cfg: &SystemConfig, sampler: &SystemSampler, policy: Policy) -> Vec<f64> {
        self.min_trs_multi(cfg, sampler, std::slice::from_ref(&policy))
            .pop()
            .expect("one policy requested")
    }

    fn min_trs_multi(
        &self,
        cfg: &SystemConfig,
        sampler: &SystemSampler,
        policies: &[Policy],
    ) -> Vec<Vec<f64>> {
        RustIdeal { threads: 1 }.min_trs_multi_scalar(cfg, sampler, policies)
    }

    fn name(&self) -> &'static str {
        "rust-f64-scalar"
    }
}

fn opts(threads: usize) -> RunOptions {
    // 8×8 = 64 trials per column, the ISSUE's small-trial-count pin shape.
    RunOptions { n_lasers: 8, n_rows: 8, threads, ..RunOptions::fast() }
}

/// name → digest for every (spec, measure) panel, computed via `run`.
fn compute_digests(run: impl Fn(&SweepSpec) -> Vec<SweepOutput>) -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    for spec in golden_specs() {
        let outputs = run(&spec);
        for (m, o) in spec.measures.iter().zip(&outputs) {
            out.insert(format!("{}/{}", spec.tag, m.slug()), output_digest(o));
        }
    }
    out
}

fn load_pins() -> BTreeMap<String, String> {
    let Ok(text) = std::fs::read_to_string(GOLDEN_PATH) else {
        return BTreeMap::new();
    };
    let Ok(json) = Json::parse(&text) else {
        return BTreeMap::new();
    };
    let Json::Obj(pairs) = json else {
        return BTreeMap::new();
    };
    pairs
        .into_iter()
        .filter_map(|(k, v)| v.as_str().map(|s| (k, s.to_string())))
        .collect()
}

fn save_pins(pins: &BTreeMap<String, String>) {
    let pairs: Vec<(&str, Json)> =
        pins.iter().map(|(k, v)| (k.as_str(), Json::str(v.clone()))).collect();
    std::fs::write(GOLDEN_PATH, Json::obj(pairs).to_pretty()).expect("write golden pins");
}

/// The one test that owns the pin file (single test fn → no write races):
/// computes digests through the sequential engine, checks the scheduler
/// agrees at several thread counts, then compares against the pins —
/// blessing any entry the file does not have yet.
#[test]
fn golden_panel_digests() {
    let sequential = compute_digests(|spec| {
        let ideal = RustIdeal { threads: 1 };
        let engine = TrialEngine::new(&ideal, 1);
        spec.run(&engine, &opts(1))
    });

    // Batched-vs-scalar agreement: the sequential digests above ran the
    // batched `RustIdeal`; recompute every panel through the scalar oracle
    // and require identity before consulting the pin file at all.
    let scalar = compute_digests(|spec| {
        let engine = TrialEngine::new(&ScalarIdeal, 1);
        spec.run(&engine, &opts(1))
    });
    assert_eq!(
        scalar, sequential,
        "batched RustIdeal drifted from the scalar trial-at-a-time oracle"
    );

    // Same bargain for the oblivious kernel: the sequential digests above
    // evaluated the fig14 CAFP panels through the batched SoA kernel
    // (`oblivious::batch`); recompute every panel through the scalar
    // per-trial oracle (`run_scheme_with`) and require identity — the full
    // tally breakdown is in the digest, so one bit of drift in any scheme's
    // record/match/classify path trips this before the pins are consulted.
    let scalar_oblivious = compute_digests(|spec| {
        let ideal = RustIdeal { threads: 1 };
        let engine = TrialEngine::new(&ideal, 1).with_scalar_oblivious();
        spec.run(&engine, &opts(1))
    });
    assert_eq!(
        scalar_oblivious, sequential,
        "batched oblivious kernel drifted from the scalar run_scheme_with oracle"
    );

    // Scheduler agreement at every thread count (incl. the CI matrix's).
    let mut threads = vec![1, 2, 8];
    if let Ok(v) = std::env::var("WDM_TEST_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if !threads.contains(&n) {
                threads.push(n);
            }
        }
    }
    for t in threads {
        let scheduled = compute_digests(|spec| {
            let token = CancelToken::new();
            run_sweep(spec, &opts(t), &Backend::Rust, None, &token, &mut |_| {})
                .expect("scheduled sweep")
                .outputs
        });
        assert_eq!(
            scheduled, sequential,
            "threads={t}: scheduler digests must match the sequential engine"
        );
    }

    // Pin check / bless.
    let bless_all = std::env::var("WDM_BLESS_GOLDEN").is_ok_and(|v| v == "1");
    let mut pins = load_pins();
    let mut blessed = Vec::new();
    for (name, digest) in &sequential {
        match pins.get(name) {
            Some(want) if !bless_all => assert_eq!(
                digest, want,
                "golden digest drifted for panel '{name}'.\n\
                 If the sampling/seed change was intentional, re-bless with\n\
                 `WDM_BLESS_GOLDEN=1 cargo test -q golden` and commit\n\
                 tests/golden_digests.json; otherwise this is a regression."
            ),
            _ => {
                pins.insert(name.clone(), digest.clone());
                blessed.push(name.clone());
            }
        }
    }
    if !blessed.is_empty() {
        save_pins(&pins);
        eprintln!(
            "golden: blessed {} digest(s) into {GOLDEN_PATH}: {}",
            blessed.len(),
            blessed.join(", ")
        );
    }
}
