//! Property-based invariants over randomized systems (testkit runner —
//! DESIGN.md "Substitutions": hand-rolled in place of proptest).

use wdm_arbiter::arbiter::{distance, ideal, matching, Policy};
use wdm_arbiter::config::SystemConfig;
use wdm_arbiter::model::{DwdmGrid, SpectralOrdering, SystemUnderTest};
use wdm_arbiter::montecarlo::cafp_tally;
use wdm_arbiter::oblivious::outcome::OutcomeClass;
use wdm_arbiter::oblivious::{run_scheme, Scheme};
use wdm_arbiter::prop_assert;
use wdm_arbiter::rng::Rng;
use wdm_arbiter::testkit::{check, check_default, PropConfig};

fn random_cfg(rng: &mut Rng) -> SystemConfig {
    let grid = match rng.below(4) {
        0 => DwdmGrid::wdm8_g200(),
        1 => DwdmGrid::wdm8_g400(),
        2 => DwdmGrid::wdm16_g200(),
        _ => DwdmGrid::wdm16_g400(),
    };
    let mut cfg = SystemConfig::table1(grid);
    if rng.below(2) == 1 {
        cfg = cfg.with_permuted_orders();
    }
    cfg.variation.ring_local_nm = rng.uniform(0.0, 4.0 * grid.spacing_nm);
    cfg.variation.grid_offset_nm = rng.uniform(0.0, 20.0);
    cfg.variation.laser_local_frac = rng.uniform(0.0, 0.45);
    cfg.variation.tr_frac = rng.uniform(0.0, 0.2);
    cfg.variation.fsr_frac = rng.uniform(0.0, 0.05);
    cfg
}

/// Policies are nested in permissiveness: LtA ⊆ LtC ⊆ LtD enforcement ⇒
/// min TR ordered the other way (paper Fig 1(b)).
#[test]
fn prop_policy_min_tr_nesting() {
    check_default("policy nesting", |rng| {
        let cfg = random_cfg(rng);
        let sut = SystemUnderTest::sample(&cfg, rng);
        let dist = distance::scaled_distance_matrix(&sut);
        let s = cfg.target_order.as_slice();
        let lta = ideal::min_tuning_range(Policy::LtA, &dist, s);
        let ltc = ideal::min_tuning_range(Policy::LtC, &dist, s);
        let ltd = ideal::min_tuning_range(Policy::LtD, &dist, s);
        prop_assert!(lta <= ltc + 1e-12, "LtA {lta} > LtC {ltc}");
        prop_assert!(ltc <= ltd + 1e-12, "LtC {ltc} > LtD {ltd}");
        Ok(())
    });
}

/// The ideal witness assignment is always achievable at its own min TR and
/// honors the policy's ordering contract.
#[test]
fn prop_ideal_witness_valid() {
    check_default("ideal witness validity", |rng| {
        let cfg = random_cfg(rng);
        let sut = SystemUnderTest::sample(&cfg, rng);
        let dist = distance::scaled_distance_matrix(&sut);
        let order = &cfg.target_order;
        for policy in Policy::all() {
            let out = ideal::arbitrate(policy, &dist, order.as_slice());
            let worst = (0..dist.n)
                .map(|i| dist.at(i, out.assignment[i]))
                .fold(f64::MIN, f64::max);
            prop_assert!(
                (worst - out.min_tr_nm).abs() < 1e-9,
                "{policy}: witness worst {worst} != min_tr {}",
                out.min_tr_nm
            );
            let ok = match policy {
                Policy::LtD => order.matches_exact(&out.assignment),
                Policy::LtC => order.matches_cyclic(&out.assignment).is_some(),
                Policy::LtA => SpectralOrdering::matches_any(&out.assignment),
            };
            prop_assert!(ok, "{policy}: ordering contract violated {:?}", out.assignment);
        }
        Ok(())
    });
}

/// LtA min TR from the generic bottleneck matcher equals brute force for
/// small N (complements the unit test with random *physical* systems).
#[test]
fn prop_bottleneck_equals_bruteforce_n8() {
    check(
        "bottleneck vs bruteforce",
        PropConfig { cases: 64, seed: 0xB0 },
        |rng| {
            let cfg = SystemConfig::default();
            let sut = SystemUnderTest::sample(&cfg, rng);
            let dist = distance::scaled_distance_matrix(&sut);
            let (t, _) = matching::bottleneck_assignment(&dist.d, 8);
            let brute = brute_bottleneck(&dist.d, 8);
            prop_assert!((t - brute).abs() < 1e-12, "hk {t} vs brute {brute}");
            Ok(())
        },
    );
}

fn brute_bottleneck(d: &[f64], n: usize) -> f64 {
    fn rec(d: &[f64], n: usize, i: usize, used: &mut [bool], cur: f64, best: &mut f64) {
        if cur >= *best {
            return;
        }
        if i == n {
            *best = cur;
            return;
        }
        for j in 0..n {
            if !used[j] {
                used[j] = true;
                rec(d, n, i + 1, used, cur.max(d[i * n + j]), best);
                used[j] = false;
            }
        }
    }
    let mut best = f64::INFINITY;
    rec(d, n, 0, &mut vec![false; n], 0.0, &mut best);
    best
}

/// Sequential tuning in *natural* order can never duplicate-lock: the
/// tuning order equals the physical order, so every earlier lock masks its
/// tone for all later (downstream) rings.
#[test]
fn prop_sequential_natural_never_duplicates() {
    check_default("sequential natural no dupl", |rng| {
        let mut cfg = random_cfg(rng);
        cfg.pre_fab_order = SpectralOrdering::natural(cfg.grid.n_ch);
        cfg.target_order = SpectralOrdering::natural(cfg.grid.n_ch);
        let sut = SystemUnderTest::sample(&cfg, rng);
        let tr = rng.uniform(0.5, 11.0);
        let res = run_scheme(Scheme::Sequential, &sut.laser, &sut.rings, &cfg.target_order, tr);
        prop_assert!(
            res.class != OutcomeClass::DuplLock,
            "dupl-lock at tr={tr}: {:?}",
            res.assignment
        );
        Ok(())
    });
}

/// VT-RS/SSM matches the ideal LtC model on Table-I-default systems: if the
/// ideal model succeeds with margin, the algorithm succeeds (the paper's
/// CAFP ≈ 0 claim).
#[test]
fn prop_vt_rs_ssm_tracks_ideal_with_margin() {
    check(
        "vt-rs-ssm ~ ideal LtC",
        PropConfig { cases: 256, seed: 0x5EED },
        |rng| {
            let cfg = SystemConfig::default();
            let sut = SystemUnderTest::sample(&cfg, rng);
            let tr = rng.uniform(1.0, 10.0);
            let dist = distance::scaled_distance_matrix(&sut);
            let min_tr = ideal::min_tuning_range(Policy::LtC, &dist, cfg.target_order.as_slice());
            // Margin keeps us off fp-boundary trials.
            if min_tr > tr - 1e-3 {
                return Ok(());
            }
            let res = run_scheme(Scheme::VtRsSsm, &sut.laser, &sut.rings, &cfg.target_order, tr);
            prop_assert!(
                res.succeeded(),
                "ideal feasible (min_tr {min_tr:.3} <= tr {tr:.3}) but vt-rs-ssm {}",
                res.class.name()
            );
            Ok(())
        },
    );
}

/// CAFP ordering across schemes holds on sampled populations:
/// seq ≥ RS/SSM ≥ VT-RS/SSM (paper Fig 14).
#[test]
fn prop_scheme_ranking() {
    let cfg = SystemConfig::default();
    for (seed, tr) in [(1u64, 4.0), (2, 6.0), (3, 8.0)] {
        let seq = cafp_tally(&cfg, Scheme::Sequential, tr, 12, 12, seed, 0);
        let rs = cafp_tally(&cfg, Scheme::RsSsm, tr, 12, 12, seed, 0);
        let vt = cafp_tally(&cfg, Scheme::VtRsSsm, tr, 12, 12, seed, 0);
        assert!(
            seq.cafp() >= rs.cafp() && rs.cafp() >= vt.cafp(),
            "tr={tr}: seq {} rs {} vt {}",
            seq.cafp(),
            rs.cafp(),
            vt.cafp()
        );
    }
}

/// Grid-offset invariance (paper Fig 7(a)): with FSR exactly N·λ_gS,
/// uniformly spaced tones and no FSR/TR variation, shifting the whole
/// laser comb by one grid spacing leaves the LtC minimum tuning range
/// unchanged per-trial (barrel-shift re-centering). With laser *local*
/// variation the invariance is only distributional — consecutive tone
/// spacings differ from λ_gS — so it is zeroed here; ring local variation
/// stays (it commutes with the global shift).
#[test]
fn prop_ltc_offset_recentering() {
    check(
        "LtC offset re-centering",
        PropConfig { cases: 64, seed: 0x0FF5 },
        |rng| {
            let mut cfg = SystemConfig::default();
            cfg.variation.grid_offset_nm = 0.0;
            cfg.variation.fsr_frac = 0.0;
            cfg.variation.tr_frac = 0.0;
            cfg.variation.laser_local_frac = 0.0;
            let mut sut = SystemUnderTest::sample(&cfg, rng);
            let s = cfg.target_order.as_slice();
            let d0 = distance::scaled_distance_matrix(&sut);
            let base = ideal::min_tuning_range(Policy::LtC, &d0, s);
            for t in &mut sut.laser.tones_nm {
                *t += cfg.grid.spacing_nm;
            }
            let d1 = distance::scaled_distance_matrix(&sut);
            let shifted = ideal::min_tuning_range(Policy::LtC, &d1, s);
            prop_assert!(
                (base - shifted).abs() < 1e-6,
                "offset changed LtC min TR: {base} -> {shifted}"
            );
            Ok(())
        },
    );
}
