//! Batched-vs-scalar bit-identity: the SoA population kernel
//! (`arbiter::batch` via `RustIdeal::min_trs_multi`) must reproduce the
//! trial-at-a-time oracle (`RustIdeal::min_trs_multi_scalar`) **bit for
//! bit** — per policy, under every scenario family, and for any
//! chunk-size / thread-count combination. This is the contract that lets
//! the hot path change shape without moving a single golden digest.

use wdm_arbiter::arbiter::Policy;
use wdm_arbiter::config::SystemConfig;
use wdm_arbiter::model::system::SystemSampler;
use wdm_arbiter::model::{CorrelationConfig, Distribution, FaultsConfig};
use wdm_arbiter::montecarlo::{
    batched_min_trs_multi, batched_min_trs_multi_tier, IdealEvaluator, RustIdeal,
};
use wdm_arbiter::util::simd;

const ALL: [Policy; 3] = [Policy::LtA, Policy::LtC, Policy::LtD];

/// One representative config per scenario family (mirrors the model-layer
/// determinism suite): distances behave differently under heavy faults
/// (infinite rows), correlation (shared structure) and non-uniform draws.
fn scenario_configs() -> Vec<(&'static str, SystemConfig)> {
    let mut out = vec![("default", SystemConfig::default())];
    let mut gauss = SystemConfig::default();
    gauss.scenario.distribution = Distribution::by_name("trimmed-gaussian").unwrap();
    out.push(("trimmed-gaussian", gauss));
    let mut bimodal = SystemConfig::default();
    bimodal.scenario.distribution = Distribution::by_name("bimodal").unwrap();
    out.push(("bimodal", bimodal));
    let mut corr = SystemConfig::default();
    corr.scenario.correlation = CorrelationConfig { gradient_nm: 2.0, corr_len: 3.0 };
    out.push(("correlated", corr));
    let mut faulty = SystemConfig::default();
    faulty.scenario.faults = FaultsConfig {
        dead_tone_p: 0.2,
        dark_ring_p: 0.2,
        weak_ring_p: 0.2,
        weak_tr_factor: 0.5,
    };
    out.push(("faulty", faulty));
    out
}

fn assert_bits_eq(got: &[Vec<f64>], want: &[Vec<f64>], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: policy count");
    for (k, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(g.len(), w.len(), "{ctx}: policy {k} trial count");
        for (t, (a, b)) in g.iter().zip(w.iter()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{ctx}: policy {k} trial {t}: batched {a} vs scalar {b}"
            );
        }
    }
}

#[test]
fn batched_matches_scalar_bitwise_across_scenarios() {
    for (name, cfg) in scenario_configs() {
        let sampler = SystemSampler::new(&cfg, 9, 11, 2024);
        let eval = RustIdeal { threads: 1 };
        let scalar = eval.min_trs_multi_scalar(&cfg, &sampler, &ALL);
        let batched = eval.min_trs_multi(&cfg, &sampler, &ALL);
        assert_bits_eq(&batched, &scalar, name);
        // Single-policy slices agree with the multi rows.
        for (k, &p) in ALL.iter().enumerate() {
            let one = eval.min_trs(&cfg, &sampler, p);
            assert_bits_eq(
                std::slice::from_ref(&one),
                std::slice::from_ref(&scalar[k]),
                &format!("{name}/{p:?} single"),
            );
        }
    }
}

#[test]
fn chunking_and_threading_never_change_results() {
    // Chunk size and worker count are pure performance knobs: every
    // combination must produce the exact sequential bits (the golden and
    // determinism suites depend on this through `RustIdeal`).
    let cfg = SystemConfig::default();
    let sampler = SystemSampler::new(&cfg, 10, 13, 4242); // 130 trials
    let reference = RustIdeal { threads: 1 }.min_trs_multi_scalar(&cfg, &sampler, &ALL);
    for chunk in [1usize, 7, 64, 4096] {
        for threads in [1usize, 2, 5] {
            let got = batched_min_trs_multi(&cfg, &sampler, &ALL, threads, chunk);
            assert_bits_eq(&got, &reference, &format!("chunk={chunk} threads={threads}"));
        }
    }
}

/// Explicit SIMD-tier axis: the batched kernel at every tier this host can
/// run (scalar always; AVX2 where detected) reproduces the oracle bit for
/// bit — distance fill, LtD/LtC shift scans and the LtA prefilter all run
/// through the lane kernels. The CI legs additionally run the whole suite
/// under `WDM_SIMD=scalar` and `WDM_SIMD=auto` to cover the env dispatch.
#[test]
fn simd_tiers_never_change_results() {
    for (name, cfg) in scenario_configs() {
        let sampler = SystemSampler::new(&cfg, 8, 9, 909);
        let reference = RustIdeal { threads: 1 }.min_trs_multi_scalar(&cfg, &sampler, &ALL);
        for tier in simd::available_tiers() {
            for chunk in [5usize, 64] {
                let got = batched_min_trs_multi_tier(&cfg, &sampler, &ALL, 2, chunk, tier);
                assert_bits_eq(&got, &reference, &format!("{name} tier={tier:?} chunk={chunk}"));
            }
        }
    }
}

#[test]
fn scalar_path_is_thread_invariant_too() {
    // The oracle itself must not depend on its worker count, otherwise the
    // equivalence above would be comparing against a moving target.
    let cfg = SystemConfig::default();
    let sampler = SystemSampler::new(&cfg, 8, 8, 7);
    let one = RustIdeal { threads: 1 }.min_trs_multi_scalar(&cfg, &sampler, &ALL);
    let four = RustIdeal { threads: 4 }.min_trs_multi_scalar(&cfg, &sampler, &ALL);
    assert_bits_eq(&four, &one, "scalar threads=4 vs 1");
}

#[test]
fn heavy_fault_populations_stay_exact() {
    // Near-certain dead tones / dark rings produce infinite rows and
    // columns — the LtA prefilter's trickiest regime (`LB = ∞` must be
    // declared feasible, matching the scalar bottleneck's `∞`).
    let mut cfg = SystemConfig::default();
    cfg.scenario.faults = FaultsConfig {
        dead_tone_p: 0.6,
        dark_ring_p: 0.6,
        weak_ring_p: 0.3,
        weak_tr_factor: 0.5,
    };
    let sampler = SystemSampler::new(&cfg, 12, 12, 555);
    let eval = RustIdeal { threads: 2 };
    let scalar = eval.min_trs_multi_scalar(&cfg, &sampler, &ALL);
    let batched = eval.min_trs_multi(&cfg, &sampler, &ALL);
    assert_bits_eq(&batched, &scalar, "heavy-faults");
    assert!(
        scalar[0].iter().any(|v| v.is_infinite()),
        "regime check: some trials should be unarbitrable at any range"
    );
}
