//! Drive the typed job API programmatically: one long-lived
//! [`ArbiterService`], several jobs, and population-cache reuse across
//! overlapping sweeps — the same mechanism behind `wdm-arbiter serve`.
//!
//! ```bash
//! cargo run --release --example job_api
//! ```

use wdm_arbiter::api::{ArbiterService, JobRequest};
use wdm_arbiter::coordinator::Backend;

fn main() {
    let service = ArbiterService::new(Backend::Rust, 0);

    // A sweep job, written exactly as a serve-mode client would send it.
    let sweep = JobRequest::from_json_str(
        r#"{
        "type": "sweep", "axis": "ring-local", "values": [1.12, 2.24, 4.48],
        "tr": [2, 4, 6, 9], "measures": ["afp:ltc", "cafp:vt-rs-ssm"],
        "options": {"fast": true, "lasers": 10, "rows": 10, "out": "out/job-api"}
    }"#,
    )
    .expect("valid job");

    let first = service.submit(&sweep);
    print!("{}", first.summary);
    println!(
        "first submit:  {} cache hits, {} misses ({} populations held)",
        first.cache.hits, first.cache.misses, first.cache.entries
    );

    // Re-submitting the same job resamples nothing: every column is a hit.
    let second = service.submit(&sweep);
    println!(
        "second submit: {} cache hits, {} misses",
        second.cache.hits, second.cache.misses
    );

    // A *different* measure over the same columns still reuses them — the
    // ideal-LtC evaluation already paid for is shared.
    let min_tr = JobRequest::from_json_str(
        r#"{
        "type": "sweep", "axis": "ring-local", "values": [1.12, 2.24, 4.48],
        "measures": ["min-tr:ltc"],
        "options": {"fast": true, "lasers": 10, "rows": 10, "out": "out/job-api"}
    }"#,
    )
    .expect("valid job");
    let third = service.submit(&min_tr);
    println!(
        "third submit:  {} cache hits, {} misses",
        third.cache.hits, third.cache.misses
    );

    // Every job is a serializable value — this line is a valid stdin line
    // for `wdm-arbiter serve`.
    println!("wire form: {}", sweep.to_json_string());
}
