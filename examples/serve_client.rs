//! TCP client for the envelope wire protocol (`wdm-arbiter serve --listen`):
//! submits two overlapping sweep jobs, cancels the long one mid-sweep, and
//! verifies the interleaved, id-tagged envelope stream.
//!
//! ```bash
//! wdm-arbiter serve --listen 127.0.0.1:0 &   # prints "listening on ADDR"
//! cargo run --release --example serve_client -- ADDR [--shutdown]
//! ```
//!
//! Prints (and checks) three markers the CI smoke greps for:
//! `interleaved envelopes: yes`, `job a: canceled`, `job b: ok`.
//! With `--shutdown` it also sends the shutdown control so the server
//! drains and exits cleanly.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::process::ExitCode;

use wdm_arbiter::util::json::Json;

/// Job "a": long enough (16 columns x 400 trials, CAFP) that the cancel —
/// sent as soon as its first event arrives — always lands mid-sweep.
fn job_a(out_dir: &str) -> String {
    format!(
        r#"{{"id": "a", "request": {{"type": "sweep", "axis": "ring-local",
            "values": "0.56:8.96:0.56", "tr": [2, 4, 6, 9],
            "measures": "cafp:vt-rs-ssm",
            "options": {{"fast": true, "lasers": 20, "rows": 20, "out": "{out_dir}/a"}}}}}}"#
    )
    .replace('\n', " ")
}

/// Job "b": a short sweep that completes normally while "a" is running.
fn job_b(out_dir: &str) -> String {
    format!(
        r#"{{"id": "b", "request": {{"type": "sweep", "axis": "ring-local",
            "values": [1.12, 2.24], "tr": [2, 6], "measures": "afp:ltc",
            "options": {{"fast": true, "lasers": 6, "rows": 6, "out": "{out_dir}/b"}}}}}}"#
    )
    .replace('\n', " ")
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("serve_client: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> Result<(), String> {
    let mut args = std::env::args().skip(1);
    let addr = args.next().ok_or("usage: serve_client HOST:PORT [--shutdown]")?;
    let shutdown = args.any(|a| a == "--shutdown");
    let out_dir = std::env::temp_dir().join(format!("serve-client-{}", std::process::id()));
    let out_dir = out_dir.display().to_string();

    let stream = TcpStream::connect(&addr).map_err(|e| format!("connect {addr}: {e}"))?;
    let mut writer = stream.try_clone().map_err(|e| e.to_string())?;
    let mut reader = BufReader::new(stream);

    writeln!(writer, "{}", job_a(&out_dir)).map_err(|e| e.to_string())?;
    writeln!(writer, "{}", job_b(&out_dir)).map_err(|e| e.to_string())?;

    let (mut resp_a, mut resp_b) = (None::<Json>, None::<Json>);
    let mut events_a = 0usize;
    let mut events_b = 0usize;
    // Events of BOTH jobs seen before EITHER response: true overlap.
    let mut overlapped = false;
    let mut cancel_sent = false;
    let mut line = String::new();
    while resp_a.is_none() || resp_b.is_none() {
        line.clear();
        let n = reader.read_line(&mut line).map_err(|e| e.to_string())?;
        if n == 0 {
            return Err("server closed the connection early".to_string());
        }
        let envelope = Json::parse(line.trim())?;
        let id = envelope.get("id").ok_or_else(|| format!("untagged line: {line}"))?;
        let id = id.as_str().unwrap_or("").to_string();
        if envelope.get("event").is_some() {
            match id.as_str() {
                "a" => events_a += 1,
                "b" => events_b += 1,
                other => return Err(format!("event for unknown job '{other}'")),
            }
            if events_a > 0 && events_b > 0 && resp_a.is_none() && resp_b.is_none() {
                overlapped = true;
            }
            // First sign of life from job "a": cancel it. The server acks
            // immediately; the job stops at its next column boundary.
            if !cancel_sent && id == "a" {
                cancel_sent = true;
                writeln!(writer, r#"{{"id": "c", "control": "cancel", "job": "a"}}"#)
                    .map_err(|e| e.to_string())?;
            }
        } else if let Some(resp) = envelope.get("response") {
            match id.as_str() {
                "a" => resp_a = Some(resp.clone()),
                "b" => resp_b = Some(resp.clone()),
                "c" => {
                    if resp.get("ok").and_then(Json::as_bool) != Some(true) {
                        return Err(format!("cancel control rejected: {}", resp.to_string()));
                    }
                }
                other => return Err(format!("response for unknown id '{other}'")),
            }
        } else {
            return Err(format!("envelope without event/response: {line}"));
        }
    }

    let a = resp_a.unwrap();
    let b = resp_b.unwrap();
    let a_canceled = a.get("canceled").and_then(Json::as_bool) == Some(true);
    let b_ok = b.get("ok").and_then(Json::as_bool) == Some(true);
    println!("events: a={events_a} b={events_b}");
    println!("interleaved envelopes: {}", if overlapped { "yes" } else { "no" });
    println!("job a: {}", if a_canceled { "canceled" } else { "NOT canceled" });
    println!("job b: {}", if b_ok { "ok" } else { "FAILED" });

    if shutdown {
        writeln!(writer, r#"{{"id": "sd", "control": "shutdown"}}"#)
            .map_err(|e| e.to_string())?;
        // The server acks, drains, and closes; read to EOF.
        let mut rest = String::new();
        let got_ack = loop {
            rest.clear();
            match reader.read_line(&mut rest) {
                Ok(0) | Err(_) => break false,
                Ok(_) => {
                    let env = Json::parse(rest.trim())?;
                    if env.get("id").and_then(Json::as_str) == Some("sd") {
                        break true;
                    }
                }
            }
        };
        println!("shutdown: {}", if got_ack { "acknowledged" } else { "NO ACK" });
        if !got_ack {
            return Err("no shutdown acknowledgement".to_string());
        }
    }

    std::fs::remove_dir_all(&out_dir).ok();
    if overlapped && a_canceled && b_ok {
        Ok(())
    } else {
        Err(format!(
            "contract violated (interleaved={overlapped} a_canceled={a_canceled} b_ok={b_ok})"
        ))
    }
}
