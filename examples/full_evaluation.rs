//! End-to-end driver: exercises the **full three-layer stack** on a real
//! workload and reports the paper's headline results.
//!
//! What runs:
//! 1. The AOT JAX/Pallas ideal-model artifact on the PJRT CPU runtime
//!    (Layer 1+2, built by `make artifacts`), cross-checked against the
//!    Rust f64 oracle and benchmarked for throughput.
//! 2. Every paper experiment (Tables I–II, Figs 4–8, 14–16) at reduced
//!    Monte-Carlo resolution, writing CSV/JSON reports to `out/full_eval/`.
//! 3. A headline table: minimum tuning ranges per policy and CAFP per
//!    scheme, with the paper's qualitative expectations alongside.
//!
//! ```bash
//! make artifacts && cargo run --release --example full_evaluation
//! ```
//!
//! Results of a recorded run live in EXPERIMENTS.md.

use std::time::Instant;

use wdm_arbiter::arbiter::Policy;
use wdm_arbiter::config::SystemConfig;
use wdm_arbiter::coordinator::{run_experiment, Backend, RunOptions};
use wdm_arbiter::experiments::all_experiments;
use wdm_arbiter::model::system::SystemSampler;
use wdm_arbiter::montecarlo::{cafp_tally, min_tr_complete, IdealEvaluator, RustIdeal};
use wdm_arbiter::oblivious::Scheme;
use wdm_arbiter::runtime::accel::XlaIdeal;

fn main() -> anyhow::Result<()> {
    println!("=== wdm-arbiter full evaluation (three-layer stack) ===\n");

    // ---- 1. runtime bring-up: artifact vs oracle ------------------------
    let cfg = SystemConfig::default();
    let rust = RustIdeal::default();
    let sampler = SystemSampler::new(&cfg, 32, 32, 0xE2E);

    match XlaIdeal::discover() {
        Ok(xla) => {
            // Warm up: the first call compiles the artifact (one-time cost).
            let _ = xla.min_trs(&cfg, &sampler, Policy::LtC);
            let t0 = Instant::now();
            let a = xla.min_trs(&cfg, &sampler, Policy::LtC);
            let xla_dt = t0.elapsed();
            let t0 = Instant::now();
            let b = rust.min_trs(&cfg, &sampler, Policy::LtC);
            let rust_dt = t0.elapsed();
            let max_err = a
                .iter()
                .zip(&b)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f64, f64::max);
            println!(
                "PJRT artifact (ideal_n8): {} trials  xla {:.1} ms vs rust {:.1} ms; max |Δ| = {:.2e} nm",
                a.len(),
                xla_dt.as_secs_f64() * 1e3,
                rust_dt.as_secs_f64() * 1e3,
                max_err
            );
            assert!(max_err < 2e-3, "artifact disagrees with oracle");
            println!("  -> Layer 1/2 (Pallas kernel + JAX model) verified against the Rust oracle\n");
        }
        Err(e) => println!("PJRT artifacts unavailable ({e}); continuing with rust backend\n"),
    }

    // ---- 2. paper experiments at reduced resolution ----------------------
    let opts = RunOptions {
        out_dir: "out/full_eval".into(),
        n_lasers: 20,
        n_rows: 20,
        fast: true,
        backend: Backend::Xla,
        ..RunOptions::fast()
    };
    let t0 = Instant::now();
    for exp in all_experiments() {
        run_experiment(exp.as_ref(), &opts)?;
    }
    println!(
        "\nall paper experiments regenerated in {:.1} s (reports in out/full_eval/)\n",
        t0.elapsed().as_secs_f64()
    );

    // ---- 3. headline table ------------------------------------------------
    println!("=== headline results (Table-I defaults, 400 trials/point) ===");
    let eval = RustIdeal::default();
    let s2 = SystemSampler::new(&cfg, 20, 20, 0xE2E2);
    let trs = eval.min_trs_multi(&cfg, &s2, &[Policy::LtA, Policy::LtC, Policy::LtD]);
    println!(
        "min TR for complete success @ sigma_rLV=2.24 nm: LtA {:.2} | LtC {:.2} | LtD {:.2}  (paper: LtA < LtC < LtD)",
        min_tr_complete(&trs[0]),
        min_tr_complete(&trs[1]),
        min_tr_complete(&trs[2])
    );
    for scheme in Scheme::all() {
        let tally = cafp_tally(&cfg, scheme, 6.0, 20, 20, 0xE2E3, 0);
        println!(
            "CAFP @ TR=6 nm {:<10}: {:.4}  (paper: seq >> rs-ssm > vt-rs-ssm ≈ 0)",
            scheme.name(),
            tally.cafp()
        );
    }
    println!("\nfull evaluation complete.");
    Ok(())
}
