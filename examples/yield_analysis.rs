//! Yield analysis: AFP as transceiver yield (paper §III-A: "AFP reflects
//! the arbitration yield, where failure to arbitrate successfully is
//! treated as transceiver failure").
//!
//! For a chosen design point this sweeps the mean tuning range and reports
//! per-policy yield (1 − AFP) with 95 % Wilson intervals, plus the end-to-
//! end VT-RS/SSM yield (1 − AFP − CAFP).
//!
//! ```bash
//! cargo run --release --example yield_analysis -- [sigma_rlv_nm] [trials-per-side]
//! ```

use wdm_arbiter::arbiter::Policy;
use wdm_arbiter::config::SystemConfig;
use wdm_arbiter::model::system::SystemSampler;
use wdm_arbiter::montecarlo::{afp_at, cafp_tally, IdealEvaluator, RustIdeal};
use wdm_arbiter::oblivious::Scheme;
use wdm_arbiter::util::stats::wilson_interval;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rlv: f64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(2.24);
    let side: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(40);

    let mut cfg = SystemConfig::default();
    cfg.variation.ring_local_nm = rlv;
    let eval = RustIdeal::default();
    let sampler = SystemSampler::new(&cfg, side, side, 0xFAB);
    let trials = sampler.n_trials();
    let min_trs = eval.min_trs_multi(&cfg, &sampler, &[Policy::LtA, Policy::LtC, Policy::LtD]);

    println!("yield vs mean tuning range @ sigma_rLV = {rlv} nm ({trials} trials)");
    println!(
        "{:>8} {:>18} {:>18} {:>18} {:>22}",
        "TR [nm]", "LtA yield", "LtC yield", "LtD yield", "VT-RS/SSM e2e yield"
    );
    for k in 1..=9 {
        let tr = k as f64 * 1.12;
        let mut row = format!("{tr:>8.2}");
        for trs in &min_trs {
            let afp = afp_at(trs, tr);
            let fails = (afp * trials as f64).round() as usize;
            let (lo, hi) = wilson_interval(trials - fails, trials);
            row.push_str(&format!(" {:>7.4} [{lo:.3},{hi:.3}]", 1.0 - afp));
        }
        // End-to-end: policy (LtC) + algorithm (VT-RS/SSM) failures.
        let tally = cafp_tally(&cfg, Scheme::VtRsSsm, tr, side, side, 0xFAB2, 0);
        row.push_str(&format!("        {:>7.4}", 1.0 - tally.total_failure()));
        println!("{row}");
    }
    println!("\nnote: LtC yield minus VT-RS/SSM e2e yield is the algorithmic cost");
    println!("(CAFP); the paper's claim is that this gap is ≈ 0.");
}
