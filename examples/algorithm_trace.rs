//! Algorithm trace: a step-by-step view of the wavelength-oblivious
//! RS/SSM pipeline on one sampled system — the runnable version of the
//! paper's Figs 9–13.
//!
//! ```bash
//! cargo run --release --example algorithm_trace -- [seed] [mean_tr_nm]
//! ```

use wdm_arbiter::arbiter::{distance, ideal, Policy};
use wdm_arbiter::config::SystemConfig;
use wdm_arbiter::model::SystemUnderTest;
use wdm_arbiter::oblivious::outcome::classify;
use wdm_arbiter::oblivious::relation::{full_record_phase, ProbeSet, RelationOutcome};
use wdm_arbiter::oblivious::ssm::match_phase;
use wdm_arbiter::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seed: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(7);
    let tr: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6.0);

    let cfg = SystemConfig::default();
    let mut rng = Rng::seed_from(seed);
    let sut = SystemUnderTest::sample(&cfg, &mut rng);

    println!("=== system (seed {seed}, λ̄_TR = {tr} nm) ===");
    println!("lasers: {:?}", round2(&sut.laser.tones_nm));
    println!("rings:  {:?}", round2(&sut.rings.resonance_nm));

    // --- record phase (paper §V-B) --------------------------------------
    let rec = full_record_phase(&sut.laser, &sut.rings, &cfg.target_order, tr, ProbeSet::FirstLastSecond);
    println!("\n=== record phase: search tables (tuner code → hidden tone) ===");
    for (i, st) in rec.tables.iter().enumerate() {
        let entries: Vec<String> = st
            .entries
            .iter()
            .map(|e| format!("{}→λ{}", e.code, e.tone))
            .collect();
        println!("  ST({i}): [{}]", entries.join(", "));
    }
    println!("\nrelation searches along the target chain {:?}:", rec.chain);
    for (k, rel) in rec.relations.iter().enumerate() {
        let a = rec.chain[k];
        let b = rec.chain[(k + 1) % rec.chain.len()];
        let desc = match rel {
            RelationOutcome::Found(d) => format!("RI delta {d}"),
            RelationOutcome::Null => "φ (clustered)".to_string(),
            RelationOutcome::Failed => "FAILED (probes disagreed)".to_string(),
        };
        println!("  (R{a} → R{b}): {desc}");
    }

    // --- matching phase (paper §V-C) -------------------------------------
    let plan = match_phase(&rec);
    println!("\n=== matching phase: single-step lock plan ===");
    let heats: Vec<Option<f64>> = plan
        .iter()
        .enumerate()
        .map(|(i, e)| e.map(|idx| rec.tables[i].entries[idx].heat_nm))
        .collect();
    for (i, e) in plan.iter().enumerate() {
        match e {
            Some(idx) => println!(
                "  R{i}: entry #{idx} (code {}, heat {:.2} nm)",
                rec.tables[i].entries[*idx].code, rec.tables[i].entries[*idx].heat_nm
            ),
            None => println!("  R{i}: NO LOCK"),
        }
    }

    // --- adjudication vs the ideal model ---------------------------------
    let res = classify(&sut.laser, &sut.rings, &heats, &cfg.target_order);
    let dist = distance::scaled_distance_matrix(&sut);
    let ideal_out = ideal::arbitrate(Policy::LtC, &dist, cfg.target_order.as_slice());
    println!("\n=== adjudication ===");
    println!("oblivious outcome: {} — tones {:?}", res.class.name(), res.assignment);
    println!(
        "ideal LtC:         min TR {:.2} nm (feasible: {}) — tones {:?}",
        ideal_out.min_tr_nm,
        ideal_out.min_tr_nm <= tr,
        ideal_out.assignment
    );
}

fn round2(v: &[f64]) -> Vec<f64> {
    v.iter().map(|x| (x * 100.0).round() / 100.0).collect()
}
