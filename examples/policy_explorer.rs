//! Policy explorer: a miniature Fig 5 — minimum tuning range vs local
//! resonance variation for all three policies on a chosen DWDM grid.
//!
//! ```bash
//! cargo run --release --example policy_explorer -- [wdm8-200g|wdm16-400g|…] [trials-per-side]
//! ```

use wdm_arbiter::arbiter::Policy;
use wdm_arbiter::config::SystemConfig;
use wdm_arbiter::model::system::SystemSampler;
use wdm_arbiter::model::DwdmGrid;
use wdm_arbiter::montecarlo::sweep::unit_multiples;
use wdm_arbiter::montecarlo::{min_tr_complete, IdealEvaluator, RustIdeal};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let grid_name = args.first().map(|s| s.as_str()).unwrap_or("wdm8-200g");
    let side: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(30);
    let grid = DwdmGrid::by_name(grid_name).unwrap_or_else(|| {
        eprintln!("unknown grid '{grid_name}', using wdm8-200g");
        DwdmGrid::wdm8_g200()
    });

    let base = SystemConfig::table1(grid);
    let eval = RustIdeal::default();
    let rlv_values = unit_multiples(grid.spacing_nm, 0.5, 8.0, 0.5);

    println!(
        "minimum mean tuning range for complete success — {} ({} trials/point)",
        grid.name(),
        side * side
    );
    println!(
        "{:>12} {:>10} {:>10} {:>10}",
        "sigma_rLV", "LtA", "LtC", "LtD"
    );
    for (i, &rlv) in rlv_values.iter().enumerate() {
        let mut cfg = base.clone();
        cfg.variation.ring_local_nm = rlv;
        let sampler = SystemSampler::new(&cfg, side, side, 7000 + i as u64);
        let trs =
            eval.min_trs_multi(&cfg, &sampler, &[Policy::LtA, Policy::LtC, Policy::LtD]);
        println!(
            "{:>12.2} {:>10.2} {:>10.2} {:>10.2}",
            rlv,
            min_tr_complete(&trs[0]),
            min_tr_complete(&trs[1]),
            min_tr_complete(&trs[2]),
        );
    }
    println!("\nexpected shapes (paper Fig 4/5): LtA ≤ LtC ≤ LtD; LtA/LtC ramp with");
    println!("slope ≈ 2 then saturate (LtC at the FSR); LtD pinned near the FSR by");
    println!("the 15 nm grid offset.");
}
