//! Quickstart: sample one DWDM transceiver system (Table I defaults),
//! arbitrate it with every policy (ideal model) and every wavelength-
//! oblivious scheme, and print what happened.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use wdm_arbiter::arbiter::{distance, ideal, Policy};
use wdm_arbiter::config::SystemConfig;
use wdm_arbiter::model::SystemUnderTest;
use wdm_arbiter::oblivious::{run_scheme, Scheme};
use wdm_arbiter::rng::Rng;

fn main() {
    // Table I defaults: 8-channel, 200 GHz grid, 15 nm grid offset, 2.24 nm
    // local resonance variation, 10 % tuning-range variation.
    let cfg = SystemConfig::default();
    let mean_tr_nm = 6.0;

    let mut rng = Rng::seed_from(2024);
    let sut = SystemUnderTest::sample(&cfg, &mut rng);

    println!("sampled multi-wavelength laser (center-relative nm):");
    println!("  {:?}", round2(&sut.laser.tones_nm));
    println!("sampled microring row resonances:");
    println!("  {:?}\n", round2(&sut.rings.resonance_nm));

    // The ideal, wavelength-aware arbitration model (paper §III-A): what a
    // policy *could* achieve if the arbiter knew every wavelength.
    let dist = distance::scaled_distance_matrix(&sut);
    println!("ideal wavelength-aware arbitration:");
    for policy in Policy::all() {
        let out = ideal::arbitrate(policy, &dist, cfg.target_order.as_slice());
        println!(
            "  {policy}: needs ≥{:5.2} nm mean tuning range; assignment {:?}",
            out.min_tr_nm, out.assignment
        );
    }

    // The wavelength-oblivious algorithms (paper §V): what the real
    // transceiver does with only tuner codes and aggressor injection.
    println!("\nwavelength-oblivious arbitration at λ̄_TR = {mean_tr_nm} nm:");
    for scheme in Scheme::all() {
        let res = run_scheme(scheme, &sut.laser, &sut.rings, &cfg.target_order, mean_tr_nm);
        println!(
            "  {:<10} -> {:<10} tones {:?}",
            scheme.name(),
            res.class.name(),
            res.assignment.iter().map(|a| a.map(|t| t as i64).unwrap_or(-1)).collect::<Vec<_>>()
        );
    }
    println!("\n(success = complete, collision-free, cyclic order preserved — the LtC contract)");
}

fn round2(v: &[f64]) -> Vec<f64> {
    v.iter().map(|x| (x * 100.0).round() / 100.0).collect()
}
